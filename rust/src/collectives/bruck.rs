//! The standard Bruck allgather — paper Algorithm 1.
//!
//! `⌈log2(p)⌉` steps. Before step `i` each rank holds `min(2^i, p)` blocks,
//! beginning with its own, in “rotated” order: block `j` is the
//! contribution of rank `(id + j) mod p`. Step `i` sends the first
//! `min(2^i, p − 2^i)` blocks to rank `id − 2^i (mod p)` and receives the
//! same amount from rank `id + 2^i (mod p)`, appended after the held
//! blocks. A final rotation (“rotate data down by id positions”) restores
//! global rank order.
//!
//! The final rotation is the data-movement hot spot mirrored by the Pallas
//! kernel `python/compile/kernels/bruck_pack.py` (see DESIGN.md).
//!
//! [`BruckPlan`] is the persistent form: the step schedule and tag block
//! are computed once, the rotated working buffer is allocated once, and
//! every [`BruckPlan::execute`] reuses them. It doubles as the inner
//! engine of the hierarchical, multi-lane and locality-aware plans.

use super::plan::{
    check_io, trivial_plan, AllgatherPlan, CollectiveAlgorithm, CollectivePlan, NamedAlgorithm,
    PlanCore, Shape,
};
use crate::comm::{Comm, Pod};
use crate::error::Result;

/// The standard Bruck algorithm (registry entry).
pub struct Bruck;

impl NamedAlgorithm for Bruck {
    fn name(&self) -> &'static str {
        "bruck"
    }

    fn summary(&self) -> &'static str {
        "standard Bruck allgather (paper Alg. 1): log2(p) steps, final rotation"
    }
}

impl<T: Pod> CollectiveAlgorithm<T> for Bruck {
    fn plan(&self, comm: &Comm, shape: Shape) -> Result<Box<dyn AllgatherPlan<T>>> {
        if let Some(p) = trivial_plan("bruck", comm, shape) {
            return Ok(p);
        }
        Ok(Box::new(BruckPlan::<T>::new(comm, shape.n)))
    }
}

/// One exchange of the Bruck schedule.
struct Step {
    send_to: usize,
    recv_from: usize,
    blocks: usize,
}

/// Persistent Bruck plan: schedule + tag block + rotated working buffer.
pub struct BruckPlan<T: Pod> {
    core: PlanCore,
    steps: Vec<Step>,
    /// Working buffer in rotated order, length `n·p`.
    data: Vec<T>,
}

impl<T: Pod> BruckPlan<T> {
    /// Collectively plan a Bruck allgather of `n` elements per rank.
    /// Reserves one collective tag per step on `comm`.
    pub fn new(comm: &Comm, n: usize) -> BruckPlan<T> {
        let p = comm.size();
        let id = comm.rank();
        let mut steps = Vec::new();
        let mut dist = 1usize;
        while dist < p {
            steps.push(Step {
                send_to: (id + p - dist) % p,
                recv_from: (id + dist) % p,
                // partial final step for non-power-of-two p
                blocks: dist.min(p - dist),
            });
            dist <<= 1;
        }
        BruckPlan {
            core: PlanCore::new(comm, n, steps.len() as u64),
            steps,
            data: vec![T::default(); n * p],
        }
    }
}

impl<T: Pod> CollectivePlan for BruckPlan<T> {
    fn algorithm(&self) -> &'static str {
        "bruck"
    }

    fn shape(&self) -> Shape {
        Shape { n: self.core.n }
    }

    fn comm_size(&self) -> usize {
        self.core.p
    }
}

impl<T: Pod> AllgatherPlan<T> for BruckPlan<T> {
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()> {
        let core = &self.core;
        check_io(core.n, core.p, input, output)?;
        if core.n == 0 {
            return Ok(());
        }
        let n = core.n;
        self.data[..n].copy_from_slice(input);
        let mut filled = n;
        for (i, s) in self.steps.iter().enumerate() {
            let tag = core.tag(i as u64);
            let _send = core.comm.isend(&self.data[..s.blocks * n], s.send_to, tag)?;
            // receive straight into the working buffer's tail (no
            // intermediate Vec)
            let req = core.comm.irecv(s.recv_from, tag);
            req.wait_into(&core.comm, &mut self.data[filled..filled + s.blocks * n])?;
            filled += s.blocks * n;
        }
        debug_assert_eq!(filled, n * core.p);
        rotate_down_into(&self.data, n, core.id, output);
        Ok(())
    }
}

/// One-shot convenience wrapper: plan + single execute.
pub fn allgather<T: Pod>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot(&Bruck, comm, local)
}

/// The final reorder of Algorithm 1, into a caller-provided buffer: the
/// rotated input holds rank `(id + j) mod p`'s block at position `j`;
/// rotating *down* by `id` blocks puts the block of rank `r` at position
/// `r`.
pub fn rotate_down_into<T: Pod>(data: &[T], n: usize, id: usize, out: &mut [T]) {
    assert!(n > 0, "block size must be positive");
    assert_eq!(data.len() % n, 0);
    assert_eq!(out.len(), data.len());
    let p = data.len() / n;
    // out[(id + j) % p] = data[j]  ⇔  out[k] = data[(k - id) mod p]
    for k in 0..p {
        let j = (k + p - id % p) % p;
        out[k * n..(k + 1) * n].copy_from_slice(&data[j * n..(j + 1) * n]);
    }
}

/// Allocating form of [`rotate_down_into`] (micro-bench / kernel-twin API).
pub fn rotate_down<T: Pod>(data: &[T], n: usize, id: usize) -> Vec<T> {
    let mut out = vec![T::default(); data.len()];
    rotate_down_into(data, n, id, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rotate_down_identity_for_rank0() {
        let data: Vec<u64> = (0..12).collect();
        assert_eq!(rotate_down(&data, 3, 0), data);
    }

    #[test]
    fn rotate_down_moves_blocks() {
        // 3 blocks of 2, rank 1: rotated order is [b1, b2, b0]; rotating
        // down by 1 restores [b0, b1, b2].
        let rotated: Vec<u64> = vec![10, 11, 20, 21, 0, 1];
        let out = rotate_down(&rotated, 2, 1);
        assert_eq!(out, vec![0, 1, 10, 11, 20, 21]);
    }

    #[test]
    fn rotate_down_wraps_modulo_p() {
        let data: Vec<u64> = (0..8).collect(); // 4 blocks of 2
        assert_eq!(rotate_down(&data, 2, 4), data); // id == p → identity
        assert_eq!(rotate_down(&data, 2, 5), rotate_down(&data, 2, 1));
    }

    #[test]
    fn plan_reuse_matches_one_shot() {
        use crate::comm::{CommWorld, Timing};
        use crate::topology::Topology;
        let topo = Topology::regions(2, 3);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let mut plan = BruckPlan::<u64>::new(c, 2);
            let mut out = vec![0u64; 12];
            for round in 0..3u64 {
                let mine = [c.rank() as u64 + 100 * round, c.rank() as u64 + 100 * round + 50];
                plan.execute(&mine, &mut out).unwrap();
                let expect: Vec<u64> = (0..6u64)
                    .flat_map(|r| [r + 100 * round, r + 100 * round + 50])
                    .collect();
                assert_eq!(out, expect, "round {round}");
            }
            true
        });
        assert!(run.results.iter().all(|&b| b));
    }
}
