//! The standard Bruck allgather — paper Algorithm 1 — as a schedule
//! builder.
//!
//! `⌈log2(p)⌉` steps. Before step `i` each rank holds `min(2^i, p)` blocks,
//! beginning with its own, in “rotated” order: block `j` is the
//! contribution of rank `(id + j) mod p`. Step `i` sends the first
//! `min(2^i, p − 2^i)` blocks to rank `id − 2^i (mod p)` and receives the
//! same amount from rank `id + 2^i (mod p)`, appended after the held
//! blocks. A final rotation (“rotate data down by id positions”) restores
//! global rank order.
//!
//! The final rotation is the data-movement hot spot mirrored by the Pallas
//! kernel `python/compile/kernels/bruck_pack.py` (see DESIGN.md); in the
//! schedule IR it is the one [`Step::Rotate`](super::schedule::Step) of
//! the schedule, whose rounds of `SendRecv` steps are Eq. 3's `⌈log2 p⌉`
//! postal terms, evaluated mechanically by [`crate::model::cost`].
//!
//! [`build_schedule`] is the whole algorithm: a pure function from
//! `(p, rank, n)` to a [`Schedule`]; planning wraps it in the generic
//! [`SchedPlan`] executor and it doubles as the inner engine of the
//! hierarchical, multi-lane and locality-aware builders (via
//! [`super::schedule::emit_group_bruck`]).

use super::plan::{
    trivial_plan, AllgatherPlan, CollectiveAlgorithm, NamedAlgorithm, OpKind, PlanSpec,
};
use super::schedule::{emit_group_bruck, SchedPlan, Schedule, ScheduleBuilder, Slice};
use crate::comm::{Comm, Pod};
use crate::error::Result;

/// The standard Bruck algorithm (registry entry).
pub struct Bruck;

impl NamedAlgorithm for Bruck {
    fn name(&self) -> &'static str {
        "bruck"
    }

    fn summary(&self) -> &'static str {
        "standard Bruck allgather (paper Alg. 1): log2(p) steps, final rotation"
    }
}

impl<T: Pod> CollectiveAlgorithm<T> for Bruck {
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn AllgatherPlan<T>>> {
        if let Some(p) = trivial_plan("bruck", comm, spec) {
            return Ok(p);
        }
        let n = spec.uniform_n("bruck")?;
        let sched = build_schedule(comm.size(), comm.rank(), n, std::mem::size_of::<T>());
        Ok(SchedPlan::<T>::boxed(comm, "bruck", sched)?)
    }
}

/// Build the Bruck allgather schedule for one rank (pure; SPMD).
pub fn build_schedule(p: usize, rank: usize, n: usize, elem_bytes: usize) -> Schedule {
    let mut sb = ScheduleBuilder::new("bruck");
    emit_group_bruck(
        &mut sb,
        &(0..p).collect::<Vec<_>>(),
        rank,
        n,
        Slice::input(0, n),
        Slice::output(0, n * p),
    );
    sb.finish(OpKind::Allgather, p, n, elem_bytes, "bruck")
}

/// One-shot convenience wrapper: plan + single execute.
pub fn allgather<T: Pod>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot(&Bruck, comm, local)
}

/// The final reorder of Algorithm 1, into a caller-provided buffer: the
/// rotated input holds rank `(id + j) mod p`'s block at position `j`;
/// rotating *down* by `id` blocks puts the block of rank `r` at position
/// `r`. Also the interpreter of [`Step::Rotate`](super::schedule::Step).
pub fn rotate_down_into<T: Pod>(data: &[T], n: usize, id: usize, out: &mut [T]) {
    assert!(n > 0, "block size must be positive");
    assert_eq!(data.len() % n, 0);
    assert_eq!(out.len(), data.len());
    let p = data.len() / n;
    // out[(id + j) % p] = data[j]  ⇔  out[k] = data[(k - id) mod p]
    for k in 0..p {
        let j = (k + p - id % p) % p;
        out[k * n..(k + 1) * n].copy_from_slice(&data[j * n..(j + 1) * n]);
    }
}

/// Allocating form of [`rotate_down_into`] (micro-bench / kernel-twin API).
pub fn rotate_down<T: Pod>(data: &[T], n: usize, id: usize) -> Vec<T> {
    let mut out = vec![T::default(); data.len()];
    rotate_down_into(data, n, id, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::plan::{Registry, Shape};

    #[test]
    fn rotate_down_identity_for_rank0() {
        let data: Vec<u64> = (0..12).collect();
        assert_eq!(rotate_down(&data, 3, 0), data);
    }

    #[test]
    fn rotate_down_moves_blocks() {
        // 3 blocks of 2, rank 1: rotated order is [b1, b2, b0]; rotating
        // down by 1 restores [b0, b1, b2].
        let rotated: Vec<u64> = vec![10, 11, 20, 21, 0, 1];
        let out = rotate_down(&rotated, 2, 1);
        assert_eq!(out, vec![0, 1, 10, 11, 20, 21]);
    }

    #[test]
    fn rotate_down_wraps_modulo_p() {
        let data: Vec<u64> = (0..8).collect(); // 4 blocks of 2
        assert_eq!(rotate_down(&data, 2, 4), data); // id == p → identity
        assert_eq!(rotate_down(&data, 2, 5), rotate_down(&data, 2, 1));
    }

    #[test]
    fn schedule_has_log2p_exchanges_and_one_rotation() {
        use crate::collectives::schedule::Step;
        let sched = build_schedule(6, 1, 2, 8);
        let mut exchanges = 0;
        let mut rotations = 0;
        for s in sched.steps() {
            match s {
                Step::SendRecv { .. } => exchanges += 1,
                Step::Rotate { .. } => rotations += 1,
                _ => {}
            }
        }
        assert_eq!(exchanges, 3); // ceil(log2 6)
        assert_eq!(rotations, 1);
        assert_eq!(sched.tags, 3);
        sched.validate().unwrap();
    }

    #[test]
    fn plan_reuse_matches_one_shot() {
        use crate::comm::{CommWorld, Timing};
        use crate::topology::Topology;
        let topo = Topology::regions(2, 3);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let mut plan =
                Registry::<u64>::standard().plan_uniform("bruck", c, Shape::elems(2)).unwrap();
            let mut out = vec![0u64; 12];
            for round in 0..3u64 {
                let mine = [c.rank() as u64 + 100 * round, c.rank() as u64 + 100 * round + 50];
                plan.execute(&mine, &mut out).unwrap();
                let expect: Vec<u64> = (0..6u64)
                    .flat_map(|r| [r + 100 * round, r + 100 * round + 50])
                    .collect();
                assert_eq!(out, expect, "round {round}");
            }
            true
        });
        assert!(run.results.iter().all(|&b| b));
    }
}
