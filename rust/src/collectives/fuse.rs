//! Schedule fusion: run several concurrent collectives as **one**
//! round-merged, message-coalesced [`Schedule`].
//!
//! The paper's core lever is reducing the *number and size of non-local
//! messages*: the locality-aware Bruck aggregates what standard Bruck
//! would send as many small inter-region messages into one message per
//! exchange partner (§3, §4 — each non-local step pays a single
//! `α_c + β_c·s` postal term instead of many `α_c` terms). Fusion lifts
//! exactly that aggregation idea from *within one collective* to *across
//! concurrent collectives*: when a serving loop issues an allgather and a
//! consensus allreduce (or `K` micro-batched allgathers) back to back,
//! their schedules usually address the same peers in the same rounds —
//! so their same-destination wire messages can be coalesced into one,
//! paying one `α_c` where sequential execution pays `N`.
//!
//! [`fuse`] is a pure per-rank function with three phases:
//!
//! 1. **Namespacing.** Each constituent's `Input`/`Output`/`Scratch`
//!    buffers are windowed into a composite buffer space
//!    ([`Schedule::io`] carries the composite lengths) and its tag block
//!    is offset into a composite tag space, so constituents can never
//!    alias each other.
//! 2. **Round alignment.** Every constituent is split into *micro-rounds*
//!    — at most one communication step each, preceded by its local steps
//!    — and the constituents' micro-round streams are zip-merged
//!    (shorter plans simply stop participating). Splitting at
//!    communication granularity is what makes the merge safe: a fused
//!    round never reorders two dependent communication steps of the same
//!    constituent.
//! 3. **Coalescing.** Within a fused round, send halves addressed to the
//!    same peer become one wire message (payloads gathered into a
//!    coalescing scratch buffer, pad bytes summed, the smallest member
//!    tag reused); receive halves from the same peer become one receive
//!    plus scatter copies. Every fused round posts all of its sends
//!    before blocking on its first receive.
//!
//! Whether both endpoints of a message group the same members is a
//! *global* property, so [`fuse_world`] builds every rank's fused
//! schedule and replays the mailbox matching ([`verify_world`]) before
//! committing; if the peers disagree (structurally dissimilar
//! constituents), it falls back to uncoalesced fusion — still one
//! schedule, still round-merged, just without message merging.
//!
//! The cost model needs no extension: a fused schedule is a schedule, so
//! [`crate::model::cost::predict`] prices it exactly and
//! [`crate::model::cost::evaluate_fusion`] reports the savings against
//! sequential execution.

use std::collections::HashMap;

use crate::error::{Error, Result};
use crate::model::MachineParams;

use super::model_tuned;
use super::plan::{Counts, ElemKind, OpKind};
use super::schedule::{
    replay_world, BufId, ReplayHandler, Round, Schedule, Slice, Step, WorldView,
};

/// One constituent of a fused plan: which operation, by which algorithm
/// (a registry name; dispatchers like `model-tuned` are resolved at build
/// time), at what per-rank shape.
#[derive(Debug, Clone)]
pub struct FuseSpec {
    /// The constituent's operation.
    pub op: OpKind,
    /// Registry name of the algorithm (case-insensitive).
    pub algo: String,
    /// Per-rank element count (the constituent's [`super::plan::Shape`]).
    /// Ragged constituents set it to `counts.total()` so zero-work specs
    /// are filtered uniformly.
    pub n: usize,
    /// Per-rank counts of a **ragged** constituent (`allgatherv` /
    /// `reduce_scatter_v`); `None` for the uniform operations.
    pub counts: Option<Counts>,
}

impl FuseSpec {
    /// A uniform constituent spec.
    pub fn new(op: OpKind, algo: &str, n: usize) -> FuseSpec {
        FuseSpec { op, algo: algo.to_string(), n, counts: None }
    }

    /// A ragged constituent spec (`allgatherv` / `reduce_scatter_v`):
    /// every rank passes the same `counts`, exactly as with the
    /// stand-alone ragged registries.
    pub fn ragged(op: OpKind, algo: &str, counts: Counts) -> FuseSpec {
        let n = counts.total();
        FuseSpec { op, algo: algo.to_string(), n, counts: Some(counts) }
    }

    /// Display label: `op/algo@n`, or `op/algo@[counts]` when ragged.
    pub fn label(&self) -> String {
        match &self.counts {
            Some(c) => format!("{}/{}@[{c}]", self.op, self.algo),
            None => format!("{}/{}@{}", self.op, self.algo, self.n),
        }
    }

    /// This rank's `(input, output)` element counts: the uniform per-op
    /// contract ([`OpKind::io_elems`]) unless the spec is ragged, in
    /// which case the counts are byte-exact per rank.
    pub fn io_elems(&self, rank: usize, p: usize) -> (usize, usize) {
        match (self.op, &self.counts) {
            (OpKind::Allgatherv, Some(c)) => (c.get(rank), c.total()),
            (OpKind::ReduceScatterV, Some(c)) => (c.total(), c.get(rank)),
            _ => self.op.io_elems(self.n, p),
        }
    }

    /// The ragged counts, required for the v-operations.
    fn ragged_counts(&self) -> Result<&[usize]> {
        match &self.counts {
            Some(c) => Ok(c.as_slice()),
            None => Err(Error::Precondition(format!(
                "constituent {} needs per-rank counts (build it with FuseSpec::ragged)",
                self.label()
            ))),
        }
    }
}

/// One coalesced wire message of a fused schedule.
#[derive(Debug, Clone)]
pub struct MergedMsg {
    /// Fused round index.
    pub round: usize,
    /// Peer communicator rank.
    pub peer: usize,
    /// True for the send side, false for the receive side.
    pub send: bool,
    /// Constituent indices whose messages were merged.
    pub parts: Vec<usize>,
    /// Total payload elements of the merged message.
    pub elems: usize,
    /// Total pad (protocol header) bytes.
    pub pad: usize,
    /// The tag the merged message travels under (smallest member tag).
    pub tag: u64,
}

/// What fusion did to one rank's schedules: wire-message counts before and
/// after coalescing, plus every merged message.
#[derive(Debug, Clone, Default)]
pub struct FuseStats {
    /// Send-side wire messages across all constituents before fusion.
    pub sends_before: usize,
    /// Send-side wire messages in the fused schedule.
    pub sends_after: usize,
    /// Every coalesced message (groups of one are not listed).
    pub merged: Vec<MergedMsg>,
    /// Bytes a *staged* fused execute memcpys through the composite
    /// input/output staging buffers per execute on this rank — exactly
    /// what the zero-copy view path
    /// ([`super::plan::FusedPlan::execute_view`]) eliminates.
    pub staging_bytes: usize,
}

/// Buffer/tag offsets of one constituent in the composite space.
struct PartMap {
    in_off: usize,
    out_off: usize,
    scratch_base: usize,
    tag_base: u64,
}

fn remap_slice(s: &Slice, m: &PartMap) -> Slice {
    match s.buf {
        BufId::Input => Slice::at(BufId::Input, s.off + m.in_off, s.len),
        BufId::Output => Slice::at(BufId::Output, s.off + m.out_off, s.len),
        BufId::Scratch(i) => Slice::at(BufId::Scratch(m.scratch_base + i), s.off, s.len),
    }
}

fn remap_local(step: &Step, m: &PartMap) -> Step {
    match step {
        Step::CopyLocal { src, dst } => {
            Step::CopyLocal { src: remap_slice(src, m), dst: remap_slice(dst, m) }
        }
        Step::Reduce { src, dst } => {
            Step::Reduce { src: remap_slice(src, m), dst: remap_slice(dst, m) }
        }
        Step::Rotate { src, dst, block, shift } => Step::Rotate {
            src: remap_slice(src, m),
            dst: remap_slice(dst, m),
            block: *block,
            shift: *shift,
        },
        _ => unreachable!("communication steps are remapped by the coalescer"),
    }
}

/// One alignment slot of a constituent: the local steps that precede its
/// communication step, plus at most one communication step. Trailing
/// local steps (final rotations, combines) form a comm-free tail slot.
struct MicroRound<'a> {
    label: &'a str,
    locals: Vec<&'a Step>,
    comm: Option<&'a Step>,
}

fn micro_rounds(sched: &Schedule) -> Vec<MicroRound<'_>> {
    let mut out = Vec::new();
    let mut locals: Vec<&Step> = Vec::new();
    let mut last_label = "";
    for round in &sched.rounds {
        last_label = round.label.as_str();
        for step in &round.steps {
            match step {
                Step::Send { .. } | Step::Recv { .. } | Step::SendRecv { .. } => {
                    out.push(MicroRound {
                        label: round.label.as_str(),
                        locals: std::mem::take(&mut locals),
                        comm: Some(step),
                    });
                }
                _ => locals.push(step),
            }
        }
    }
    if !locals.is_empty() {
        out.push(MicroRound { label: last_label, locals, comm: None });
    }
    out
}

/// Half of a communication step, namespaced into the composite space.
struct Half {
    part: usize,
    peer: usize,
    slice: Slice,
    tag: u64,
    pad: usize,
}

/// Group halves by peer in first-occurrence order (every half its own
/// group when coalescing is off).
fn group_by_peer(halves: Vec<Half>, coalesce: bool) -> Vec<Vec<Half>> {
    if !coalesce {
        return halves.into_iter().map(|h| vec![h]).collect();
    }
    let mut order: Vec<usize> = Vec::new();
    let mut groups: HashMap<usize, Vec<Half>> = HashMap::new();
    for h in halves {
        if !groups.contains_key(&h.peer) {
            order.push(h.peer);
        }
        groups.entry(h.peer).or_default().push(h);
    }
    order.into_iter().map(|p| groups.remove(&p).expect("peer came from order")).collect()
}

/// Fuse constituent schedules of one rank into a single composite
/// schedule, with peer coalescing. See the [module docs](self).
pub fn fuse(parts: &[Schedule]) -> Result<Schedule> {
    Ok(fuse_with_stats(parts, true)?.0)
}

/// [`fuse`] with explicit coalescing control, also returning the
/// [`FuseStats`] coalescing report of this rank.
pub fn fuse_with_stats(parts: &[Schedule], coalesce: bool) -> Result<(Schedule, FuseStats)> {
    let Some(first) = parts.first() else {
        return Err(Error::Precondition("fuse() needs at least one schedule".into()));
    };
    let p = first.p;
    let elem_bytes = first.elem_bytes;
    for s in parts {
        if s.p != p || s.elem_bytes != elem_bytes {
            return Err(Error::Precondition(format!(
                "fused schedules must agree on communicator and element size \
                 (got p {} vs {}, elem_bytes {} vs {})",
                s.p, p, s.elem_bytes, elem_bytes
            )));
        }
    }

    // Composite buffer and tag spaces (namespacing).
    let mut maps = Vec::with_capacity(parts.len());
    let (mut in_len, mut out_len) = (0usize, 0usize);
    let mut tags = 0u64;
    let mut scratch: Vec<usize> = Vec::new();
    for s in parts {
        let (il, ol) = s.io_lens();
        maps.push(PartMap {
            in_off: in_len,
            out_off: out_len,
            scratch_base: scratch.len(),
            tag_base: tags,
        });
        in_len += il;
        out_len += ol;
        tags += s.tags;
        scratch.extend_from_slice(&s.scratch);
    }

    let micro: Vec<Vec<MicroRound>> = parts.iter().map(micro_rounds).collect();
    let nrounds = micro.iter().map(|m| m.len()).max().unwrap_or(0);

    let mut stats = FuseStats::default();
    let mut rounds = Vec::with_capacity(nrounds);
    for k in 0..nrounds {
        let mut steps: Vec<Step> = Vec::new();
        let mut labels: Vec<&str> = Vec::new();
        let mut sends: Vec<Half> = Vec::new();
        let mut recvs: Vec<Half> = Vec::new();
        for (pi, mrs) in micro.iter().enumerate() {
            let Some(mr) = mrs.get(k) else { continue };
            if !labels.contains(&mr.label) {
                labels.push(mr.label);
            }
            let m = &maps[pi];
            for &st in &mr.locals {
                steps.push(remap_local(st, m));
            }
            match mr.comm {
                Some(Step::Send { to, src, tag, pad }) => sends.push(Half {
                    part: pi,
                    peer: *to,
                    slice: remap_slice(src, m),
                    tag: m.tag_base + tag,
                    pad: *pad,
                }),
                Some(Step::Recv { from, dst, tag, pad }) => recvs.push(Half {
                    part: pi,
                    peer: *from,
                    slice: remap_slice(dst, m),
                    tag: m.tag_base + tag,
                    pad: *pad,
                }),
                Some(Step::SendRecv { to, src, from, dst, tag, pad }) => {
                    sends.push(Half {
                        part: pi,
                        peer: *to,
                        slice: remap_slice(src, m),
                        tag: m.tag_base + tag,
                        pad: *pad,
                    });
                    recvs.push(Half {
                        part: pi,
                        peer: *from,
                        slice: remap_slice(dst, m),
                        tag: m.tag_base + tag,
                        pad: *pad,
                    });
                }
                _ => {}
            }
        }
        stats.sends_before += sends.len();

        // All sends of the round are posted before its first (blocking)
        // receive — the classic safe ordering for merged SPMD programs.
        for group in group_by_peer(sends, coalesce) {
            stats.sends_after += 1;
            if group.len() == 1 {
                let h = &group[0];
                steps.push(Step::Send { to: h.peer, src: h.slice, tag: h.tag, pad: h.pad });
            } else {
                let total: usize = group.iter().map(|h| h.slice.len).sum();
                let pad: usize = group.iter().map(|h| h.pad).sum();
                let tag = group.iter().map(|h| h.tag).min().expect("non-empty group");
                let peer = group[0].peer;
                let buf = BufId::Scratch(scratch.len());
                scratch.push(total);
                let mut off = 0usize;
                for h in &group {
                    steps.push(Step::CopyLocal {
                        src: h.slice,
                        dst: Slice::at(buf, off, h.slice.len),
                    });
                    off += h.slice.len;
                }
                steps.push(Step::Send { to: peer, src: Slice::at(buf, 0, total), tag, pad });
                stats.merged.push(MergedMsg {
                    round: k,
                    peer,
                    send: true,
                    parts: group.iter().map(|h| h.part).collect(),
                    elems: total,
                    pad,
                    tag,
                });
            }
        }
        let mut scatters: Vec<Step> = Vec::new();
        for group in group_by_peer(recvs, coalesce) {
            if group.len() == 1 {
                let h = &group[0];
                steps.push(Step::Recv { from: h.peer, dst: h.slice, tag: h.tag, pad: h.pad });
            } else {
                let total: usize = group.iter().map(|h| h.slice.len).sum();
                let pad: usize = group.iter().map(|h| h.pad).sum();
                let tag = group.iter().map(|h| h.tag).min().expect("non-empty group");
                let peer = group[0].peer;
                let buf = BufId::Scratch(scratch.len());
                scratch.push(total);
                steps.push(Step::Recv { from: peer, dst: Slice::at(buf, 0, total), tag, pad });
                let mut off = 0usize;
                for h in &group {
                    scatters.push(Step::CopyLocal {
                        src: Slice::at(buf, off, h.slice.len),
                        dst: h.slice,
                    });
                    off += h.slice.len;
                }
                stats.merged.push(MergedMsg {
                    round: k,
                    peer,
                    send: false,
                    parts: group.iter().map(|h| h.part).collect(),
                    elems: total,
                    pad,
                    tag,
                });
            }
        }
        steps.extend(scatters);
        rounds.push(Round { label: labels.join(" ⊕ "), steps });
    }

    stats.staging_bytes = (in_len + out_len) * elem_bytes;

    let label = format!(
        "fused[{}]",
        parts.iter().map(|s| s.label.as_str()).collect::<Vec<_>>().join(" ⊕ ")
    );
    let sched = Schedule {
        op: first.op,
        p,
        n: in_len,
        elem_bytes,
        label,
        rounds,
        scratch,
        tags,
        io: Some((in_len, out_len)),
    };
    Ok((sched, stats))
}

/// The framing-check replay handler: every message carries its wire byte
/// count; a receive whose size disagrees with the matched send is a
/// framing error. The other meaning of the shared mailbox-replay walker
/// (`replay_world` in [`super::schedule`] — the cost model's postal
/// handler is the first).
struct FramingCheck<'a> {
    scheds: &'a [Schedule],
}

impl ReplayHandler for FramingCheck<'_> {
    type Msg = usize;

    fn on_send(&mut self, rank: usize, _to: usize, src: &Slice, _tag: u64, pad: usize) -> usize {
        self.scheds[rank].wire_bytes(src.len, pad)
    }

    fn on_recv(
        &mut self,
        rank: usize,
        from: usize,
        dst: &Slice,
        tag: u64,
        pad: usize,
        got: usize,
    ) -> Result<()> {
        let want = self.scheds[rank].wire_bytes(dst.len, pad);
        if got != want {
            return Err(Error::Precondition(format!(
                "fused schedules disagree on message framing: rank {rank} expects {want} wire \
                 bytes from rank {from} (tag {tag}) but the sender posted {got}"
            )));
        }
        Ok(())
    }
}

/// Replay the mailbox matching of a whole world of schedules (FIFO per
/// `(src, dst, tag)`, like the transport) and verify that every receive
/// matches a send of exactly the same wire size, that no receive
/// deadlocks, and that no sent message is left unconsumed. Pure — this is
/// how [`fuse_world`] decides whether peer-grouped coalescing agreed on
/// both endpoints of every wire message. The walking itself is the shared
/// `replay_world` pass also used by [`crate::model::cost::predict`].
pub fn verify_world(scheds: &[Schedule]) -> Result<()> {
    let leftover = replay_world(scheds, "fused schedule set", &mut FramingCheck { scheds })?;
    if leftover {
        return Err(Error::Precondition(
            "fused schedule set leaks messages: a send has no matching receive".into(),
        ));
    }
    Ok(())
}

/// Build every rank's schedule for one constituent spec (dispatchers
/// resolved: `model-tuned` scores candidates against `machine` exactly as
/// its registry entry does).
pub fn build_world(
    spec: &FuseSpec,
    view: &WorldView,
    elem_bytes: usize,
    machine: &MachineParams,
) -> Result<Vec<Schedule>> {
    if spec.algo.eq_ignore_ascii_case("model-tuned") {
        let (_, scheds) = match spec.op {
            OpKind::Allgather => model_tuned::pick_allgather(view, machine, spec.n, elem_bytes)?,
            OpKind::Allreduce => model_tuned::pick_allreduce(view, machine, spec.n, elem_bytes)?,
            OpKind::Alltoall => model_tuned::pick_alltoall(view, machine, spec.n, elem_bytes)?,
            OpKind::ReduceScatter => {
                model_tuned::pick_reduce_scatter(view, machine, spec.n, elem_bytes)?
            }
            OpKind::Allgatherv => {
                model_tuned::pick_allgatherv(view, machine, spec.ragged_counts()?, elem_bytes)?
            }
            OpKind::ReduceScatterV => model_tuned::pick_reduce_scatter_v(
                view,
                machine,
                spec.ragged_counts()?,
                elem_bytes,
            )?,
        };
        return Ok(scheds);
    }
    (0..view.p)
        .map(|r| match spec.op {
            OpKind::Allgather => {
                let algo = super::Algorithm::parse_or_err(&spec.algo)?;
                super::schedule::build_allgather(algo, view, r, spec.n, elem_bytes)
            }
            OpKind::Allreduce => {
                super::schedule::build_allreduce(&spec.algo, view, r, spec.n, elem_bytes)
            }
            OpKind::Alltoall => {
                super::schedule::build_alltoall(&spec.algo, view, r, spec.n, elem_bytes)
            }
            OpKind::ReduceScatter => {
                super::schedule::build_reduce_scatter(&spec.algo, view, r, spec.n, elem_bytes)
            }
            OpKind::Allgatherv => super::allgatherv::build_allgatherv(
                &spec.algo,
                view,
                r,
                spec.ragged_counts()?,
                elem_bytes,
            ),
            OpKind::ReduceScatterV => super::reduce_scatter_v::build_reduce_scatter_v(
                &spec.algo,
                view,
                r,
                spec.ragged_counts()?,
                elem_bytes,
            ),
        })
        .collect()
}

/// The trivial composite schedule of a world with nothing to communicate.
fn empty_fused(p: usize, elem_bytes: usize) -> Schedule {
    Schedule {
        op: OpKind::Allgather,
        p,
        n: 0,
        elem_bytes,
        label: "fused[]".to_string(),
        rounds: Vec::new(),
        scratch: Vec::new(),
        tags: 0,
        io: Some((0, 0)),
    }
}

/// Fuse a whole world: build every rank's constituent schedules for the
/// `n > 0` specs, fuse each rank with peer coalescing, and verify with
/// [`verify_world`] that every coalesced message is framed identically on
/// both endpoints; fall back to uncoalesced fusion when it is not.
///
/// Returns each rank's fused schedule plus each rank's [`FuseStats`]
/// (constituent indices in the stats refer to the `n > 0` specs, in
/// order). Deterministic — every rank of an SPMD world computes the same
/// result, which is what keeps fused planning collective without
/// communication.
pub fn fuse_world(
    specs: &[FuseSpec],
    view: &WorldView,
    elem_bytes: usize,
    machine: &MachineParams,
) -> Result<(Vec<Schedule>, Vec<FuseStats>)> {
    let live: Vec<FuseSpec> = specs.iter().filter(|s| s.n > 0).cloned().collect();
    if live.is_empty() {
        let empty = empty_fused(view.p, elem_bytes);
        return Ok((vec![empty; view.p], vec![FuseStats::default(); view.p]));
    }
    let mut worlds = Vec::with_capacity(live.len());
    for spec in &live {
        worlds.push(build_world(spec, view, elem_bytes, machine)?);
    }
    let mut fallback_err = None;
    for coalesce in [true, false] {
        let mut fused = Vec::with_capacity(view.p);
        let mut stats = Vec::with_capacity(view.p);
        for r in 0..view.p {
            let parts: Vec<Schedule> = worlds.iter().map(|w| w[r].clone()).collect();
            let (f, st) = fuse_with_stats(&parts, coalesce)?;
            fused.push(f);
            stats.push(st);
        }
        match verify_world(&fused) {
            Ok(()) => return Ok((fused, stats)),
            Err(e) => fallback_err = Some(e),
        }
    }
    Err(fallback_err.unwrap_or_else(|| {
        Error::Precondition("fused schedules could not be made consistent".into())
    }))
}

/// [`fuse_world`] for constituents of **different element types**: each
/// spec carries its own [`ElemKind`]. Every constituent world is built at
/// its native element size, then rescaled to byte granularity
/// ([`Schedule::scale_to_bytes`] — wire framing, padding and cost are
/// unchanged) so the `elem_bytes`-agreement precondition of [`fuse`]
/// holds trivially and the composite schedule is byte-exact.
///
/// Besides the per-rank fused schedules and stats, returns each rank's
/// **scratch-kind table**: the element kind of every composite scratch
/// buffer, in order — the constituents' own scratches first (each tagged
/// with its constituent's kind; reduce-scatter/allreduce builders only
/// allocate scratch on member ranks, so the table genuinely differs per
/// rank), then the coalescing scratches appended by [`fuse`] (tagged
/// [`ElemKind::Raw`]: they are gather/scatter staging only, never
/// `Reduce` targets). The mixed view executor uses this table to resolve
/// reduction types ([`super::plan::FusedPlanMixed`]).
pub fn fuse_world_mixed(
    specs: &[(FuseSpec, ElemKind)],
    view: &WorldView,
    machine: &MachineParams,
) -> Result<(Vec<Schedule>, Vec<FuseStats>, Vec<Vec<ElemKind>>)> {
    for (s, k) in specs {
        if *k == ElemKind::Raw {
            return Err(Error::Precondition(format!(
                "constituent {} has no element kind (raw segments cannot be planned)",
                s.label()
            )));
        }
    }
    let live: Vec<(FuseSpec, ElemKind)> =
        specs.iter().filter(|(s, _)| s.n > 0).cloned().collect();
    if live.is_empty() {
        let empty = empty_fused(view.p, 1);
        return Ok((
            vec![empty; view.p],
            vec![FuseStats::default(); view.p],
            vec![Vec::new(); view.p],
        ));
    }
    let mut worlds = Vec::with_capacity(live.len());
    for (spec, kind) in &live {
        let world = build_world(spec, view, kind.bytes(), machine)?;
        worlds.push(world.iter().map(Schedule::scale_to_bytes).collect::<Vec<_>>());
    }
    let mut fallback_err = None;
    for coalesce in [true, false] {
        let mut fused = Vec::with_capacity(view.p);
        let mut stats = Vec::with_capacity(view.p);
        let mut kinds = Vec::with_capacity(view.p);
        for r in 0..view.p {
            let parts: Vec<Schedule> = worlds.iter().map(|w| w[r].clone()).collect();
            let mut ks: Vec<ElemKind> = Vec::new();
            for ((_, kind), part) in live.iter().zip(&parts) {
                ks.extend(std::iter::repeat(*kind).take(part.scratch.len()));
            }
            let (f, st) = fuse_with_stats(&parts, coalesce)?;
            // Coalescing scratches are appended after the namespaced
            // per-part scratches, in order.
            ks.extend(std::iter::repeat(ElemKind::Raw).take(f.scratch.len() - ks.len()));
            fused.push(f);
            stats.push(st);
            kinds.push(ks);
        }
        match verify_world(&fused) {
            Ok(()) => return Ok((fused, stats, kinds)),
            Err(e) => fallback_err = Some(e),
        }
    }
    Err(fallback_err.unwrap_or_else(|| {
        Error::Precondition("fused schedules could not be made consistent".into())
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::schedule::ScheduleBuilder;

    /// A two-rank toy schedule: each rank sends one `n`-element message to
    /// the other and receives one back (one exchange slot).
    fn toy(rank: usize, n: usize) -> Schedule {
        let mut sb = ScheduleBuilder::new("toy");
        let tag = sb.tag();
        let s = sb.scratch(n);
        sb.sendrecv(1 - rank, Slice::input(0, n), 1 - rank, Slice::at(s, 0, n), tag, 0);
        sb.copy(Slice::at(s, 0, n), Slice::output(0, n));
        sb.finish(OpKind::Allreduce, 2, n, 8, "toy")
    }

    #[test]
    fn fuse_namespaces_tags_scratch_and_io() {
        let parts = vec![toy(0, 2), toy(0, 3)];
        let (f, st) = fuse_with_stats(&parts, true).unwrap();
        assert_eq!(f.tags, 2);
        assert_eq!(f.io, Some((5, 5)));
        assert_eq!(f.io_lens(), (5, 5));
        // 2 original scratches + 1 coalesced send + 1 coalesced recv
        assert_eq!(f.scratch.len(), 4);
        f.validate().unwrap();
        // both sends merged into one wire message to rank 1
        assert_eq!(st.sends_before, 2);
        assert_eq!(st.sends_after, 1);
        assert_eq!(st.merged.len(), 2); // one send-side, one recv-side
        assert!(st.merged.iter().any(|m| m.send && m.peer == 1 && m.elems == 5));
    }

    #[test]
    fn fused_world_of_toys_verifies_and_uncoalesced_too() {
        for coalesce in [true, false] {
            let fused: Vec<Schedule> = (0..2)
                .map(|r| {
                    let parts = vec![toy(r, 2), toy(r, 3)];
                    fuse_with_stats(&parts, coalesce).unwrap().0
                })
                .collect();
            verify_world(&fused).unwrap();
        }
    }

    #[test]
    fn verify_world_rejects_mismatched_framing() {
        // rank 0 fused (coalesced), rank 1 unfused: the merged 5-element
        // message from rank 0 never matches rank 1's two receives.
        let f0 = fuse_with_stats(&[toy(0, 2), toy(0, 3)], true).unwrap().0;
        let f1 = fuse_with_stats(&[toy(1, 2), toy(1, 3)], false).unwrap().0;
        let err = verify_world(&[f0, f1]).unwrap_err().to_string();
        assert!(
            err.contains("framing") || err.contains("deadlock") || err.contains("leak"),
            "{err}"
        );
    }

    #[test]
    fn shorter_plans_pad_out() {
        // one-slot toy ⊕ comm-free local plan: fused has the toy's slots.
        let mut sb = ScheduleBuilder::new("local");
        sb.copy(Slice::input(0, 1), Slice::output(0, 1));
        let local = sb.finish(OpKind::Allreduce, 2, 1, 8, "local");
        let (f, st) = fuse_with_stats(&[toy(0, 2), local], true).unwrap();
        assert_eq!(st.sends_before, 1);
        assert_eq!(st.sends_after, 1);
        assert!(st.merged.is_empty());
        f.validate().unwrap();
        assert_eq!(f.io_lens(), (3, 3));
    }

    #[test]
    fn mismatched_worlds_are_rejected() {
        let a = toy(0, 2); // p = 2
        let mut sb = ScheduleBuilder::new("x");
        sb.copy(Slice::input(0, 1), Slice::output(0, 1));
        let b = sb.finish(OpKind::Allreduce, 3, 1, 8, "x"); // p = 3
        assert!(fuse(&[a, b]).is_err());
        assert!(fuse(&[]).is_err());
    }

    #[test]
    fn fuse_world_handles_all_zero_specs() {
        let topo = crate::topology::Topology::regions(2, 2);
        let view = WorldView::world(&topo);
        let specs = vec![FuseSpec::new(OpKind::Allgather, "bruck", 0)];
        let (fused, stats) = fuse_world(&specs, &view, 8, &MachineParams::lassen()).unwrap();
        assert_eq!(fused.len(), 4);
        assert_eq!(stats.len(), 4);
        assert_eq!(fused[0].num_steps(), 0);
        assert_eq!(fused[0].io_lens(), (0, 0));
    }

    #[test]
    fn fuse_world_accepts_ragged_constituents() {
        let topo = crate::topology::Topology::regions(2, 2);
        let view = WorldView::world(&topo);
        let counts = Counts::new(vec![3, 0, 2, 1]);
        let specs = vec![
            FuseSpec::ragged(OpKind::Allgatherv, "bruck", counts.clone()),
            FuseSpec::new(OpKind::Allreduce, "loc-aware", 2),
        ];
        let m = MachineParams::lassen();
        let (fused, _) = fuse_world(&specs, &view, 8, &m).unwrap();
        verify_world(&fused).unwrap();
        // Composite io is per rank: this rank's ragged slot + the uniform
        // allreduce's n on both sides.
        assert_eq!(fused[0].io_lens(), (counts.get(0) + 2, counts.total() + 2));
        assert_eq!(fused[1].io_lens(), (counts.get(1) + 2, counts.total() + 2));
    }

    #[test]
    fn ragged_spec_io_and_label() {
        let spec = FuseSpec::ragged(OpKind::Allgatherv, "ring", Counts::new(vec![4, 0, 7, 2]));
        assert_eq!(spec.n, 13);
        assert_eq!(spec.io_elems(0, 4), (4, 13));
        assert_eq!(spec.io_elems(1, 4), (0, 13));
        assert_eq!(spec.label(), "allgatherv/ring@[4,0,7,2]");
        let rsv = FuseSpec::ragged(OpKind::ReduceScatterV, "ring", Counts::new(vec![4, 0, 7, 2]));
        assert_eq!(rsv.io_elems(2, 4), (13, 7));
        // A v-op spec without counts is rejected at build time.
        let bare = FuseSpec::new(OpKind::Allgatherv, "ring", 3);
        let view = WorldView::world(&crate::topology::Topology::regions(2, 2));
        let err = build_world(&bare, &view, 8, &MachineParams::lassen()).unwrap_err();
        assert!(err.to_string().contains("counts"), "{err}");
    }

    #[test]
    fn serving_fusion_coalesces_nonlocal_exchanges() {
        // The acceptance shape: loc-bruck allgather ⊕ loc-aware allreduce
        // on the serving topology. Their non-local exchange slots align
        // with identical peers, so coalescing must merge them: the fused
        // world carries strictly fewer wire messages than its parts.
        let topo = crate::topology::Topology::regions(2, 8);
        let view = WorldView::world(&topo);
        let specs = vec![
            FuseSpec::new(OpKind::Allgather, "loc-bruck", 4),
            FuseSpec::new(OpKind::Allreduce, "loc-aware", 2),
        ];
        let m = MachineParams::lassen();
        let (fused, stats) = fuse_world(&specs, &view, 8, &m).unwrap();
        verify_world(&fused).unwrap();
        let before: usize = stats.iter().map(|s| s.sends_before).sum();
        let after: usize = stats.iter().map(|s| s.sends_after).sum();
        assert!(after < before, "no coalescing happened: {after} !< {before}");
        assert!(stats.iter().any(|s| !s.merged.is_empty()));
    }
}
