//! PAT — Parallel Aggregated Trees — allgather and reduce-scatter as
//! schedule builders (Jeaugey, NVIDIA; NCCL's `PAT` algorithm, see
//! PAPERS.md).
//!
//! PAT runs one binomial tree **per destination block** over the ring
//! distance: the block travelling from rank `s` to rank `d` hops along
//! the binary decomposition of `(d − s) mod p`, so every block arrives in
//! at most `⌈log₂ p⌉` hops — for **any** `p`, not just powers of two.
//! The trees are then *aggregated*: at each step every rank talks to a
//! single peer at ring offset `2^k`, and all blocks whose decomposition
//! contains bit `k` at that point ride one contiguous message. The result
//! is a `⌈log₂ p⌉`-message schedule that fills the gap between the ring
//! (`p−1` latency-bound messages) and recursive halving/doubling
//! (log-depth but power-of-two-only).
//!
//! * **reduce-scatter** — steps run `k = ⌈log₂ p⌉−1 … 0` (most
//!   significant bit first). Rank `r` keeps an accumulator whose block
//!   `o` is the partial sum destined to rank `(r − o) mod p`. Before
//!   step `k` the live window is blocks `[0, min(2^{k+1}, p))`; the step
//!   sends blocks `[2^k, min(2^{k+1}, p))` — every partial whose
//!   remaining distance has bit `k` set — to rank `r − 2^k (mod p)` and
//!   folds the symmetric partials received from `r + 2^k (mod p)` into
//!   blocks `[0, min(2^{k+1}, p) − 2^k)`. Each source's contribution to
//!   each destination is counted exactly once because the hop set is the
//!   unique binary decomposition of the ring distance. Per rank:
//!   `⌈log₂ p⌉` messages, `(p−1)·n` elements — the same volume as the
//!   ring in logarithmically fewer (aggregated) messages.
//! * **allgather** — the mirrored trees, run least significant bit
//!   first: rank `r` appends blocks `[2^k, 2^k + min(2^k, p − 2^k))` in
//!   Bruck's rotated layout at step `k`. Aggregating the per-destination
//!   trees of the allgather direction reproduces exactly the Bruck
//!   exchange pattern (same peers, sizes, and final rotation), so the
//!   two schedules are cost-isomorphic; the builder is kept as an
//!   explicit PAT construction and as the inverse twin of the
//!   reduce-scatter above.
//!
//! Both builders are pure `(p, rank, n) → Schedule` functions executed by
//! the generic [`SchedPlan`] interpreter, so they run unmodified on the
//! in-process backend, the proc backend, and inside fused plans, and the
//! cost model prices them mechanically (prediction == traced vtime).
//! There are no shape preconditions: any `p ≥ 1`, `n == 0` plans are the
//! uniform no-op.

use super::plan::{
    trivial_plan, trivial_rs_plan, AllgatherPlan, CollectiveAlgorithm, NamedAlgorithm, OpKind,
    PlanSpec, ReduceScatterAlgorithm, ReduceScatterPlan, Summable,
};
use super::schedule::{ceil_log2_u64, SchedPlan, Schedule, ScheduleBuilder, Slice};
use crate::comm::{Comm, Pod};
use crate::error::Result;

/// PAT allgather (registry entry).
pub struct PatAllgather;

impl NamedAlgorithm for PatAllgather {
    fn name(&self) -> &'static str {
        "pat"
    }

    fn summary(&self) -> &'static str {
        "parallel aggregated trees (NCCL PAT): log-depth binomial-tree allgather, any p"
    }
}

impl<T: Pod> CollectiveAlgorithm<T> for PatAllgather {
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn AllgatherPlan<T>>> {
        if let Some(p) = trivial_plan("pat", comm, spec) {
            return Ok(p);
        }
        let n = spec.uniform_n("pat")?;
        let sched =
            build_pat_allgather_schedule(comm.size(), comm.rank(), n, std::mem::size_of::<T>());
        Ok(SchedPlan::<T>::boxed(comm, "pat", sched)?)
    }
}

/// PAT reduce-scatter (registry entry).
pub struct PatReduceScatter;

impl NamedAlgorithm for PatReduceScatter {
    fn name(&self) -> &'static str {
        "pat"
    }

    fn summary(&self) -> &'static str {
        "parallel aggregated trees (NCCL PAT): log-depth reduce-scatter, any p"
    }
}

impl<T: Summable> ReduceScatterAlgorithm<T> for PatReduceScatter {
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn ReduceScatterPlan<T>>> {
        if let Some(p) = trivial_rs_plan("pat", comm, spec) {
            return Ok(p);
        }
        let n = spec.uniform_n("pat")?;
        let sched =
            build_pat_rs_schedule(comm.size(), comm.rank(), n, std::mem::size_of::<T>());
        Ok(SchedPlan::<T>::boxed(comm, "pat", sched)?)
    }
}

/// Build the PAT allgather schedule for one rank (pure; SPMD).
///
/// Bruck's rotated layout carried by ascending tree levels: before step
/// `k` the accumulator holds blocks `[0, 2^k)` (block `j` = contribution
/// of rank `(rank + j) mod p`); step `k` sends the first
/// `min(2^k, p − 2^k)` blocks to `rank − 2^k (mod p)` and appends the
/// same count from `rank + 2^k (mod p)`. One final rotation restores
/// global rank order.
pub fn build_pat_allgather_schedule(
    p: usize,
    rank: usize,
    n: usize,
    elem_bytes: usize,
) -> Schedule {
    let mut sb = ScheduleBuilder::new("pat gather");
    let steps = ceil_log2_u64(p) as usize;
    let tag0 = sb.tag_block(steps as u64);
    if p == 1 {
        sb.copy(Slice::input(0, n), Slice::output(0, n));
        return sb.finish(OpKind::Allgather, p, n, elem_bytes, "pat");
    }
    let acc = sb.scratch(n * p);
    sb.copy(Slice::input(0, n), Slice::at(acc, 0, n));
    for k in 0..steps {
        let jump = 1usize << k;
        let cnt = jump.min(p - jump);
        sb.round(format!("pat level {k} (offset {jump})"));
        sb.sendrecv(
            (rank + p - jump) % p,
            Slice::at(acc, 0, cnt * n),
            (rank + jump) % p,
            Slice::at(acc, jump * n, cnt * n),
            tag0 + k as u64,
            0,
        );
    }
    sb.round("final rotation");
    if n > 0 {
        sb.rotate(Slice::at(acc, 0, n * p), Slice::output(0, n * p), n, rank);
    }
    sb.finish(OpKind::Allgather, p, n, elem_bytes, "pat")
}

/// Build the PAT reduce-scatter schedule for one rank (pure; SPMD).
///
/// Accumulator block `o` holds the partial destined to rank
/// `(rank − o) mod p`; tree levels run most significant bit first, each
/// folding the received partials into the shrinking live window. See the
/// module docs for the per-step window invariant.
pub fn build_pat_rs_schedule(p: usize, rank: usize, n: usize, elem_bytes: usize) -> Schedule {
    let mut sb = ScheduleBuilder::new("pat scatter partials");
    let steps = ceil_log2_u64(p) as usize;
    let tag0 = sb.tag_block(steps as u64);
    let acc = sb.scratch(n * p);
    // Block o of my input is my contribution to rank o, so the partial
    // destined to (rank − o) mod p starts as input block (rank − o) mod p.
    for o in 0..p {
        sb.copy(Slice::input(((rank + p - o) % p) * n, n), Slice::at(acc, o * n, n));
    }
    if p > 1 {
        let max_cnt = (0..steps)
            .map(|k| {
                let jump = 1usize << k;
                (2 * jump).min(p) - jump
            })
            .max()
            .unwrap_or(0);
        let tmp = sb.scratch(max_cnt * n);
        for (ti, k) in (0..steps).rev().enumerate() {
            let jump = 1usize << k;
            let cnt = (2 * jump).min(p) - jump;
            sb.round(format!("pat level {k} (offset {jump})"));
            sb.sendrecv(
                (rank + p - jump) % p,
                Slice::at(acc, jump * n, cnt * n),
                (rank + jump) % p,
                Slice::at(tmp, 0, cnt * n),
                tag0 + ti as u64,
                0,
            );
            sb.reduce(Slice::at(tmp, 0, cnt * n), Slice::at(acc, 0, cnt * n));
        }
    }
    sb.copy(Slice::at(acc, 0, n), Slice::output(0, n));
    sb.finish(OpKind::ReduceScatter, p, n, elem_bytes, "pat")
}

/// One-shot PAT allgather: plan + single execute.
pub fn allgather<T: Pod>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot(&PatAllgather, comm, local)
}

/// One-shot PAT reduce-scatter: `send.len()` must be a multiple of the
/// communicator size (block length inferred).
pub fn reduce_scatter<T: Summable>(comm: &Comm, send: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot_rs(&PatReduceScatter, comm, send)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::schedule::Step;
    use crate::comm::{CommWorld, Timing};
    use crate::topology::Topology;

    fn send_buf(rank: usize, p: usize, n: usize) -> Vec<u64> {
        (0..p * n)
            .map(|x| (rank * 1_000_003 + (x / n) * 1_009 + x % n) as u64)
            .collect()
    }

    fn rs_expected(rank: usize, p: usize, n: usize) -> Vec<u64> {
        (0..n)
            .map(|j| (0..p).map(|r| (r * 1_000_003 + rank * 1_009 + j) as u64).sum())
            .collect()
    }

    #[test]
    fn pat_allgather_correct_on_power_and_non_power_sizes() {
        for (regions, ppr) in [(1usize, 1usize), (1, 4), (4, 4), (3, 2), (5, 2), (7, 1), (2, 3)] {
            let topo = Topology::regions(regions, ppr);
            let p = topo.size();
            let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                let mine: Vec<u64> = (0..2).map(|j| (c.rank() * 100 + j) as u64).collect();
                allgather(c, &mine).unwrap()
            });
            let expect: Vec<u64> =
                (0..p).flat_map(|r| [(r * 100) as u64, (r * 100 + 1) as u64]).collect();
            for (r, out) in run.results.iter().enumerate() {
                assert_eq!(out, &expect, "{regions}x{ppr} rank {r}");
            }
        }
    }

    #[test]
    fn pat_reduce_scatter_correct_on_power_and_non_power_sizes() {
        for (regions, ppr) in [(1usize, 1usize), (1, 4), (4, 4), (3, 2), (5, 2), (7, 1), (3, 3)] {
            let topo = Topology::regions(regions, ppr);
            let p = topo.size();
            let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                reduce_scatter(c, &send_buf(c.rank(), p, 3)).unwrap()
            });
            for (r, out) in run.results.iter().enumerate() {
                assert_eq!(out, &rs_expected(r, p, 3), "{regions}x{ppr} rank {r}");
            }
        }
    }

    #[test]
    fn pat_schedules_have_ceil_log2_p_messages() {
        for p in [2usize, 3, 4, 5, 6, 7, 8, 12, 16] {
            let want = ceil_log2_u64(p) as usize;
            for sched in
                [build_pat_allgather_schedule(p, 1, 2, 8), build_pat_rs_schedule(p, 1, 2, 8)]
            {
                sched.validate().unwrap();
                let exchanges =
                    sched.steps().filter(|s| matches!(s, Step::SendRecv { .. })).count();
                assert_eq!(exchanges, want, "p={p} label={}", sched.label);
                assert_eq!(sched.tags, want as u64, "p={p}");
            }
        }
    }

    #[test]
    fn pat_reduce_scatter_moves_ring_volume_in_log_messages() {
        // Total sent volume is (p−1)·n elements per rank — the ring's
        // volume — carried by ⌈log₂ p⌉ aggregated messages.
        for p in [4usize, 5, 6, 8, 11] {
            let n = 3usize;
            let sched = build_pat_rs_schedule(p, 0, n, 8);
            let sent: usize = sched
                .steps()
                .filter_map(|s| match s {
                    Step::SendRecv { src, .. } => Some(src.len),
                    _ => None,
                })
                .sum();
            assert_eq!(sent, (p - 1) * n, "p={p}");
        }
    }
}
