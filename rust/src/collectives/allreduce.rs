//! Locality-aware allreduce — the paper's §6 future-work extension — as
//! schedule builders.
//!
//! “Locality-awareness can be extended to other collectives, removing
//! duplicate non-local messages for small data sizes …” We implement the
//! natural transfer of Algorithm 2's structure to a sum-allreduce and
//! compare it against standard recursive-doubling allreduce:
//!
//! * **`recursive-doubling`**: `log2(p)` exchanges of the full vector,
//!   most of them non-local (requires power-of-two `p`, checked at plan
//!   time);
//! * **`loc-aware`**: reduce within each region (local allreduce), then
//!   `⌈log_pℓ(r)⌉` exchange-and-reduce rounds among regions in which local
//!   rank `ℓ` pairs with region `g ± ℓ·pℓ^i` (local rank 0 idles), each
//!   closed by a local allgatherv + combine — `⌈log_pℓ(r)⌉` non-local
//!   messages per rank;
//! * **`rabenseifner`**: the classic reduce-scatter + allgather
//!   composition (Rabenseifner '04, the formulation Jocksch et al.
//!   optimise): a recursive-halving reduce-scatter over element ranges
//!   followed by a recursive-doubling allgather, each `log₂(p')` steps of
//!   `≈ n/2, n/4, …` elements. **Any** communicator size: non-power-of-two
//!   `p` folds the `p − p'` highest ranks into partners up front (one
//!   full-vector send + reduce) and folds the result back out at the end,
//!   so no plan-time power-of-two precondition remains;
//! * **`loc-rabenseifner`**: the fully hierarchical composition (Bienz et
//!   al., *Node-Aware Improvements to Allreduce* — both phases
//!   locality-aware). Phase 1 (all-local): a direct reduce-scatter within
//!   each region leaves local rank `ℓ` with the region's partial of chunk
//!   `ℓ` of the vector. Phase 2 (the only non-local traffic): lane `ℓ` —
//!   one member per region — runs a Rabenseifner allreduce of its
//!   `≈ n/ppr` chunk among the `r` regions, so every non-local message is
//!   an aggregated per-region partial of a `1/ppr`-sized subvector.
//!   Phase 3 (all-local): an allgatherv of the fully reduced chunks
//!   within each region. Any region count (the lane Rabenseifner folds);
//!   `ppr == 1` falls back to plain `rabenseifner`.
//!
//! Both build [`Schedule`]s whose reductions are explicit
//! [`Step::Reduce`](super::schedule::Step) steps, executed by the one
//! generic interpreter with the [`Summable`] reducer — groups, round
//! schedules, tag blocks and scratch are all schedule data; `execute` is
//! pure communication + summation with zero allocation and no tag
//! consumption. Shape preconditions (power-of-two sizes, uniform groups)
//! surface at `plan()` time; `n == 0` plans are uniform no-ops.

use super::grouping::GroupBy;
use super::plan::{
    trivial_reduce_plan, AllreduceAlgorithm, AllreducePlan, NamedAlgorithm, OpKind, PlanSpec,
};
use super::schedule::{
    ceil_log2_u64, emit_group_allgatherv, emit_group_rd_allreduce, locate, uniform_size,
    SchedPlan, Schedule, ScheduleBuilder, Slice, WorldView,
};
use crate::comm::Comm;
use crate::error::Result;

/// Element types that can be summed (re-exported from the plan framework;
/// the reduction used by the paper's allreduce reference [4]).
pub use super::plan::Summable;

/// Standard recursive-doubling allreduce (registry entry).
pub struct RecursiveDoublingAllreduce;

impl NamedAlgorithm for RecursiveDoublingAllreduce {
    fn name(&self) -> &'static str {
        "recursive-doubling"
    }

    fn summary(&self) -> &'static str {
        "recursive-doubling allreduce: log2(p) full-vector exchanges, power-of-two p only"
    }
}

impl<T: Summable> AllreduceAlgorithm<T> for RecursiveDoublingAllreduce {
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn AllreducePlan<T>>> {
        if let Some(p) = trivial_reduce_plan("recursive-doubling", comm, spec) {
            return Ok(p);
        }
        let n = spec.uniform_n("recursive-doubling")?;
        let sched = build_rd_schedule(comm.size(), comm.rank(), n, std::mem::size_of::<T>())?;
        Ok(SchedPlan::<T>::boxed(comm, "recursive-doubling", sched)?)
    }
}

/// Build the recursive-doubling allreduce schedule for one rank (pure;
/// SPMD). Errors on non-power-of-two communicators.
pub fn build_rd_schedule(
    p: usize,
    rank: usize,
    n: usize,
    elem_bytes: usize,
) -> Result<Schedule> {
    let mut sb = ScheduleBuilder::new("recursive doubling");
    sb.copy(Slice::input(0, n), Slice::output(0, n));
    let members: Vec<usize> = (0..p).collect();
    emit_group_rd_allreduce(&mut sb, &members, rank, n)?;
    Ok(sb.finish(OpKind::Allreduce, p, n, elem_bytes, "recursive-doubling"))
}

/// True if Algorithm 2's round structure sums every region exactly once
/// for `r_n` regions of `ppr` ranks: every round width `ppr^i < r_n` must
/// divide `r_n`, otherwise the wrap-around groups of the allgather (which
/// are idempotent there) would double-count partial sums here.
pub fn locality_rounds_align(r_n: usize, ppr: usize) -> bool {
    if ppr < 2 {
        return false;
    }
    let mut w = 1usize;
    while w < r_n {
        if r_n % w != 0 {
            return false;
        }
        w = w.saturating_mul(ppr);
    }
    true
}

/// The locality-aware regional allreduce (registry entry).
pub struct LocalityAwareAllreduce;

impl NamedAlgorithm for LocalityAwareAllreduce {
    fn name(&self) -> &'static str {
        "loc-aware"
    }

    fn summary(&self) -> &'static str {
        "regional allreduce (§6): local reduce, log_ppr(r) sparse non-local rounds"
    }
}

impl<T: Summable> AllreduceAlgorithm<T> for LocalityAwareAllreduce {
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn AllreducePlan<T>>> {
        if let Some(p) = trivial_reduce_plan("loc-aware", comm, spec) {
            return Ok(p);
        }
        let n = spec.uniform_n("loc-aware")?;
        let view = WorldView::from_comm(comm);
        let sched = build_loc_schedule(&view, comm.rank(), n, std::mem::size_of::<T>())?;
        Ok(SchedPlan::<T>::boxed(comm, "loc-aware", sched)?)
    }
}

/// Build the locality-aware allreduce schedule for one rank (pure; SPMD).
///
/// Summation is not idempotent, so the non-local rounds require aligned
/// groups ([`locality_rounds_align`]); single-region, single-rank-per-
/// region and unaligned shapes fall back to a recursive-doubling schedule
/// (whose power-of-two precondition then also surfaces at plan time).
pub fn build_loc_schedule(
    view: &WorldView,
    rank: usize,
    n: usize,
    elem_bytes: usize,
) -> Result<Schedule> {
    let all: Vec<usize> = (0..view.p).collect();
    let groups = view.split(&all, GroupBy::Region);
    let ppr = uniform_size(&groups, "locality-aware allreduce")?;
    let r_n = groups.len();
    if r_n == 1 || ppr == 1 || !locality_rounds_align(r_n, ppr) {
        let mut sched = build_rd_schedule(view.p, rank, n, elem_bytes)?;
        sched.label = "loc-aware[recursive-doubling]".to_string();
        return Ok(sched);
    }
    let (g, l) = locate(&groups, rank)?;

    let mut sb = ScheduleBuilder::new("local allreduce");
    // Phase 1: allreduce within the region → every rank holds its region's
    // sum (plan-time error if ppr is not a power of two).
    sb.copy(Slice::input(0, n), Slice::output(0, n));
    emit_group_rd_allreduce(&mut sb, &groups[g], rank, n)?;

    // Invariant per round: every rank of region g holds the exact sum over
    // regions [g, g+width) mod r_n. Local rank j ≥ 1 fetches the disjoint
    // group [g + j·width, g + (j+1)·width); alignment (checked above)
    // guarantees no group wraps into held regions.
    let mut width = 1usize;
    let mut round_no = 1usize;
    while width < r_n {
        sb.round(format!("non-local round {round_no}"));
        let tag = sb.tag();
        let blocks = (r_n / width).min(ppr); // groups reachable this round
        let active_j = |j: usize| j > 0 && j < blocks;
        let active = active_j(l);
        let recv = if active { Some(sb.scratch(n)) } else { None };
        if let Some(rbuf) = recv {
            let dist = (l * width) % r_n;
            let to = groups[(g + r_n - dist) % r_n][l];
            let from = groups[(g + dist) % r_n][l];
            sb.sendrecv(to, Slice::output(0, n), from, Slice::at(rbuf, 0, n), tag, 0);
        }
        // Local allgatherv of the received partials, then combine.
        let counts: Vec<usize> = (0..ppr).map(|j| if active_j(j) { n } else { 0 }).collect();
        let total: usize = counts.iter().sum();
        let gathered = sb.scratch(total);
        let contrib = match recv {
            Some(rbuf) => Slice::at(rbuf, 0, n),
            None => Slice::input(0, 0),
        };
        emit_group_allgatherv(
            &mut sb,
            &groups[g],
            rank,
            &counts,
            contrib,
            Slice::at(gathered, 0, total),
        );
        for c in 0..total / n {
            sb.reduce(Slice::at(gathered, c * n, n), Slice::output(0, n));
        }
        width = width.saturating_mul(ppr);
        round_no += 1;
    }
    Ok(sb.finish(OpKind::Allreduce, view.p, n, elem_bytes, "loc-aware"))
}

/// The Rabenseifner allreduce (registry entry): reduce-scatter +
/// allgather, valid for any communicator size.
pub struct RabenseifnerAllreduce;

impl NamedAlgorithm for RabenseifnerAllreduce {
    fn name(&self) -> &'static str {
        "rabenseifner"
    }

    fn summary(&self) -> &'static str {
        "reduce-scatter + allgather allreduce; any p via a fold-in step, no power-of-two precondition"
    }
}

impl<T: Summable> AllreduceAlgorithm<T> for RabenseifnerAllreduce {
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn AllreducePlan<T>>> {
        if let Some(p) = trivial_reduce_plan("rabenseifner", comm, spec) {
            return Ok(p);
        }
        let n = spec.uniform_n("rabenseifner")?;
        let sched =
            build_rabenseifner_schedule(comm.size(), comm.rank(), n, std::mem::size_of::<T>());
        Ok(SchedPlan::<T>::boxed(comm, "rabenseifner", sched)?)
    }
}

/// Element offset of chunk boundary `j` when an `n`-vector is split into
/// `q` contiguous chunks (`⌊n·j/q⌋`; both peers of an exchange compute the
/// identical boundaries, so uneven chunks — including empty ones when
/// `n < q` — need no negotiation).
fn chunk_off(n: usize, q: usize, j: usize) -> usize {
    n * j / q
}

/// Build the Rabenseifner allreduce schedule for one rank (pure; SPMD).
///
/// Let `p'` be the largest power of two `≤ p`. The `p − p'` highest ranks
/// fold their vectors into partner ranks `0..p−p'` and idle; the `p'`
/// survivors run a recursive-halving reduce-scatter over element ranges
/// (halving phase of Jocksch et al.'s formulation: step `i` exchanges
/// `≈ n/2^i` elements with the partner `rank XOR p'/2^i`, reducing the
/// kept half), then the mirror-image recursive-doubling allgather; the
/// folded ranks finally receive the full result. No size precondition:
/// any `p ≥ 1` builds.
pub fn build_rabenseifner_schedule(
    p: usize,
    rank: usize,
    n: usize,
    elem_bytes: usize,
) -> Schedule {
    let mut sb = ScheduleBuilder::new("fold-in");
    sb.copy(Slice::input(0, n), Slice::output(0, n));
    let members: Vec<usize> = (0..p).collect();
    emit_rabenseifner(&mut sb, &members, rank, 0, n);
    sb.finish(OpKind::Allreduce, p, n, elem_bytes, "rabenseifner")
}

/// Emit a Rabenseifner allreduce among `members` over the element range
/// `Output[off, off+len)`, which every member must already hold its
/// partial of. Any group size: let `q` be the largest power of two
/// `≤ |members|`; the `|members| − q` highest members fold their ranges
/// into partners up front and receive the result at the end, the `q`
/// survivors run the recursive-halving reduce-scatter + recursive-
/// doubling allgather over sub-ranges. Ranks outside `members` allocate
/// the tag block and emit nothing (the SPMD contract). A single-member
/// group is a no-op.
pub(crate) fn emit_rabenseifner(
    sb: &mut ScheduleBuilder,
    members: &[usize],
    me: usize,
    off: usize,
    len: usize,
) {
    let m = members.len();
    let q = if m.is_power_of_two() { m } else { m.next_power_of_two() >> 1 };
    let rem = m - q;
    let logq = ceil_log2_u64(q);
    let t_in = sb.tag();
    let t_rs = sb.tag_block(logq);
    let t_ag = sb.tag_block(logq);
    let t_out = sb.tag();
    let Some(k) = members.iter().position(|&r| r == me) else {
        return;
    };
    if k >= q {
        // Folded member: contribute the whole range, then wait for the
        // reduced result.
        sb.send(members[k - q], Slice::output(off, len), t_in, 0);
        sb.round("fold-out");
        sb.recv(members[k - q], Slice::output(off, len), t_out, 0);
        return;
    }
    if k < rem {
        let folded = sb.scratch(len);
        sb.recv(members[q + k], Slice::at(folded, 0, len), t_in, 0);
        sb.reduce(Slice::at(folded, 0, len), Slice::output(off, len));
    }
    if q > 1 {
        // Phase 1: recursive-halving reduce-scatter over element ranges.
        // Invariant: the aligned chunk window [lo, lo+w) is owned by the
        // aligned member group [lo, lo+w); each step halves both, keeping
        // the half containing `k`.
        sb.round("reduce-scatter (recursive halving)");
        let tmp = sb.scratch(len);
        let (mut lo, mut w, mut ti) = (0usize, q, 0u64);
        while w > 1 {
            let half = w / 2;
            let peer = members[k ^ half];
            let (keep_lo, send_lo) = if k & half == 0 { (lo, lo + half) } else { (lo + half, lo) };
            let s0 = chunk_off(len, q, send_lo);
            let s1 = chunk_off(len, q, send_lo + half);
            let k0 = chunk_off(len, q, keep_lo);
            let k1 = chunk_off(len, q, keep_lo + half);
            sb.sendrecv(
                peer,
                Slice::output(off + s0, s1 - s0),
                peer,
                Slice::at(tmp, 0, k1 - k0),
                t_rs + ti,
                0,
            );
            sb.reduce(Slice::at(tmp, 0, k1 - k0), Slice::output(off + k0, k1 - k0));
            lo = keep_lo;
            w = half;
            ti += 1;
        }
        debug_assert_eq!(lo, k);
        // Phase 2: recursive-doubling allgather, reversing the halving —
        // each step trades the owned range with member `k XOR w` and the
        // two windows merge.
        sb.round("allgather (recursive doubling)");
        let (mut lo, mut w, mut tj) = (k, 1usize, 0u64);
        while w < q {
            let peer = members[k ^ w];
            let peer_lo = lo ^ w;
            let m0 = chunk_off(len, q, lo);
            let m1 = chunk_off(len, q, lo + w);
            let o0 = chunk_off(len, q, peer_lo);
            let o1 = chunk_off(len, q, peer_lo + w);
            sb.sendrecv(
                peer,
                Slice::output(off + m0, m1 - m0),
                peer,
                Slice::output(off + o0, o1 - o0),
                t_ag + tj,
                0,
            );
            lo &= !w;
            w <<= 1;
            tj += 1;
        }
    }
    if k < rem {
        sb.round("fold-out");
        sb.send(members[q + k], Slice::output(off, len), t_out, 0);
    }
}

/// The fully hierarchical Rabenseifner allreduce (registry entry): both
/// phases locality-aware.
pub struct LocRabenseifnerAllreduce;

impl NamedAlgorithm for LocRabenseifnerAllreduce {
    fn name(&self) -> &'static str {
        "loc-rabenseifner"
    }

    fn summary(&self) -> &'static str {
        "hierarchical Rabenseifner: local reduce-scatter, per-lane inter-region allreduce of one chunk, local allgather"
    }
}

impl<T: Summable> AllreduceAlgorithm<T> for LocRabenseifnerAllreduce {
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn AllreducePlan<T>>> {
        if let Some(p) = trivial_reduce_plan("loc-rabenseifner", comm, spec) {
            return Ok(p);
        }
        let n = spec.uniform_n("loc-rabenseifner")?;
        let view = WorldView::from_comm(comm);
        let sched =
            build_loc_rabenseifner_schedule(&view, comm.rank(), n, std::mem::size_of::<T>())?;
        Ok(SchedPlan::<T>::boxed(comm, "loc-rabenseifner", sched)?)
    }
}

/// Build the fully hierarchical Rabenseifner allreduce schedule for one
/// rank (pure; SPMD).
///
/// The vector is chunked over the `ppr` local ranks of each region
/// (boundaries via [`chunk_off`], so uneven and empty chunks need no
/// negotiation):
///
/// 1. **local reduce-scatter** — every member sends each local peer `m`
///    its input's chunk `m`; local rank `ℓ` reduces the region's partial
///    of chunk `ℓ` in place. All-local, `ppr − 1` messages of `≈ n/ppr`;
/// 2. **lane allreduce** — lane `ℓ` (the ranks with local index `ℓ`, one
///    per region) runs [`emit_rabenseifner`] on chunk `ℓ` among the `r`
///    regions: the schedule's only non-local messages, every one an
///    aggregated per-region partial of the `1/ppr`-sized chunk;
/// 3. **local allgather** — an allgatherv of the fully reduced chunks
///    within each region restores the complete vector everywhere.
///
/// Any region count (the lane emitter folds non-powers of two);
/// `ppr == 1` (nothing local to split over) falls back to the plain
/// Rabenseifner schedule; non-uniform regions are rejected at plan time.
pub fn build_loc_rabenseifner_schedule(
    view: &WorldView,
    rank: usize,
    n: usize,
    elem_bytes: usize,
) -> Result<Schedule> {
    let all: Vec<usize> = (0..view.p).collect();
    let groups = view.split(&all, GroupBy::Region);
    let ppr = uniform_size(&groups, "hierarchical Rabenseifner allreduce")?;
    if ppr == 1 {
        let mut sched = build_rabenseifner_schedule(view.p, rank, n, elem_bytes);
        sched.label = "loc-rabenseifner[rabenseifner]".to_string();
        return Ok(sched);
    }
    let (g, l) = locate(&groups, rank)?;

    let mut sb = ScheduleBuilder::new("local reduce-scatter");
    // Phase 1: chunk the vector over the region's members; local rank ℓ
    // reduces the region's partial of chunk ℓ in place. The input buffer
    // is stable, so peers' chunks are sent straight from it — no staging.
    sb.copy(Slice::input(0, n), Slice::output(0, n));
    let my0 = chunk_off(n, ppr, l);
    let my1 = chunk_off(n, ppr, l + 1);
    let t_local = sb.tag();
    for (m, &peer) in groups[g].iter().enumerate() {
        if m == l {
            continue;
        }
        let c0 = chunk_off(n, ppr, m);
        let c1 = chunk_off(n, ppr, m + 1);
        sb.send(peer, Slice::input(c0, c1 - c0), t_local, 0);
    }
    let tmp = sb.scratch(my1 - my0);
    for (m, &peer) in groups[g].iter().enumerate() {
        if m == l {
            continue;
        }
        sb.recv(peer, Slice::at(tmp, 0, my1 - my0), t_local, 0);
        sb.reduce(Slice::at(tmp, 0, my1 - my0), Slice::output(my0, my1 - my0));
    }

    // Phase 2: allreduce of chunk ℓ among the lane — one member per
    // region; the only non-local traffic of the schedule.
    sb.round("lane allreduce");
    let lane: Vec<usize> = groups.iter().map(|group| group[l]).collect();
    emit_rabenseifner(&mut sb, &lane, rank, my0, my1 - my0);

    // Phase 3: gather the fully reduced chunks within the region.
    sb.round("local allgather");
    let counts: Vec<usize> =
        (0..ppr).map(|m| chunk_off(n, ppr, m + 1) - chunk_off(n, ppr, m)).collect();
    emit_group_allgatherv(
        &mut sb,
        &groups[g],
        rank,
        &counts,
        Slice::output(my0, my1 - my0),
        Slice::output(0, n),
    );
    Ok(sb.finish(OpKind::Allreduce, view.p, n, elem_bytes, "loc-rabenseifner"))
}

/// One-shot standard recursive-doubling allreduce: plan + single execute
/// (requires power-of-two size, surfaced before any communication).
pub fn allreduce_recursive_doubling<T: Summable>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot_reduce(&RecursiveDoublingAllreduce, comm, local)
}

/// One-shot Rabenseifner allreduce: plan + single execute; any `p`.
pub fn allreduce_rabenseifner<T: Summable>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot_reduce(&RabenseifnerAllreduce, comm, local)
}

/// One-shot locality-aware allreduce: plan + single execute. Unaligned or
/// locality-free shapes fall back to recursive doubling.
pub fn allreduce_locality_aware<T: Summable>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot_reduce(&LocalityAwareAllreduce, comm, local)
}

/// One-shot fully hierarchical Rabenseifner allreduce: plan + single
/// execute; any `p` with uniform regions (`ppr == 1` falls back to the
/// plain Rabenseifner).
pub fn allreduce_loc_rabenseifner<T: Summable>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot_reduce(&LocRabenseifnerAllreduce, comm, local)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::plan::{AllreduceRegistry, Shape};
    use crate::comm::{CommWorld, Timing};
    use crate::topology::Topology;

    fn expected_sum(p: usize, n: usize) -> Vec<u64> {
        // rank r contributes [r, r+1, ..]: sum over r of (r + j)
        (0..n)
            .map(|j| (0..p).map(|r| (r + j) as u64).sum())
            .collect()
    }

    fn contribution(rank: usize, n: usize) -> Vec<u64> {
        (0..n).map(|j| (rank + j) as u64).collect()
    }

    #[test]
    fn recursive_doubling_sums() {
        let topo = Topology::regions(2, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allreduce_recursive_doubling(c, &contribution(c.rank(), 3)).unwrap()
        });
        for r in &run.results {
            assert_eq!(r, &expected_sum(8, 3));
        }
    }

    #[test]
    fn locality_aware_matches_recursive_doubling() {
        for (regions, ppr) in [(4usize, 4usize), (2, 2), (16, 4), (4, 8)] {
            let topo = Topology::regions(regions, ppr);
            let p = topo.size();
            let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                allreduce_locality_aware(c, &contribution(c.rank(), 2)).unwrap()
            });
            for r in &run.results {
                assert_eq!(r, &expected_sum(p, 2), "regions={regions} ppr={ppr}");
            }
        }
    }

    #[test]
    fn locality_aware_fewer_nonlocal_messages() {
        let topo = Topology::regions(16, 4); // p = 64
        let std = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allreduce_recursive_doubling(c, &contribution(c.rank(), 4)).unwrap();
        });
        let loc = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allreduce_locality_aware(c, &contribution(c.rank(), 4)).unwrap();
        });
        assert!(
            loc.trace.max_nonlocal_msgs() < std.trace.max_nonlocal_msgs(),
            "loc {} vs std {}",
            loc.trace.max_nonlocal_msgs(),
            std.trace.max_nonlocal_msgs()
        );
    }

    #[test]
    fn rabenseifner_sums_at_any_size() {
        // Powers of two, odd sizes, and the fold-in remainder cases.
        for (regions, ppr) in [(1usize, 1usize), (1, 2), (4, 4), (3, 1), (5, 2), (3, 3), (2, 3)] {
            let topo = Topology::regions(regions, ppr);
            let p = topo.size();
            let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                allreduce_rabenseifner(c, &contribution(c.rank(), 5)).unwrap()
            });
            for r in &run.results {
                assert_eq!(r, &expected_sum(p, 5), "regions={regions} ppr={ppr}");
            }
        }
    }

    #[test]
    fn rabenseifner_handles_vectors_shorter_than_the_chunk_count() {
        // n < p': some chunk ranges are empty; zero-length exchanges are
        // still posted and the single real element still converges.
        let topo = Topology::regions(4, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allreduce_rabenseifner(c, &contribution(c.rank(), 1)).unwrap()
        });
        for r in &run.results {
            assert_eq!(r, &expected_sum(16, 1));
        }
    }

    #[test]
    fn loc_rabenseifner_sums_on_aligned_ragged_and_degenerate_shapes() {
        // Power-of-two and non-power-of-two region counts, single-region,
        // ppr = 1 (plain-Rabenseifner fallback), and n < ppr (empty
        // chunks).
        for (regions, ppr, n) in [
            (4usize, 4usize, 5usize),
            (2, 2, 2),
            (3, 3, 4),
            (2, 3, 7),
            (5, 2, 3),
            (1, 4, 3),
            (4, 1, 3),
            (4, 4, 1),
        ] {
            let topo = Topology::regions(regions, ppr);
            let p = topo.size();
            let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                allreduce_loc_rabenseifner(c, &contribution(c.rank(), n)).unwrap()
            });
            for r in &run.results {
                assert_eq!(r, &expected_sum(p, n), "regions={regions} ppr={ppr} n={n}");
            }
        }
    }

    #[test]
    fn loc_rabenseifner_moves_fewer_nonlocal_bytes_than_plain() {
        // (4,4): plain Rabenseifner's two largest exchanges (n/2 and n/4
        // each way) cross regions; the hierarchical variant's non-local
        // traffic is the lane allreduce of one n/4 chunk — strictly fewer
        // non-local bytes on every rank.
        let topo = Topology::regions(4, 4);
        let n = 64usize;
        let plain = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allreduce_rabenseifner(c, &contribution(c.rank(), n)).unwrap();
        });
        let loc = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allreduce_loc_rabenseifner(c, &contribution(c.rank(), n)).unwrap();
        });
        assert!(
            loc.trace.total_nonlocal_bytes() < plain.trace.total_nonlocal_bytes(),
            "loc {} B !< plain {} B",
            loc.trace.total_nonlocal_bytes(),
            plain.trace.total_nonlocal_bytes()
        );
        for (l, p) in loc.trace.per_rank.iter().zip(plain.trace.per_rank.iter()) {
            assert!(
                l.nonlocal_bytes < p.nonlocal_bytes,
                "per-rank: loc {} B !< plain {} B",
                l.nonlocal_bytes,
                p.nonlocal_bytes
            );
        }
    }

    #[test]
    fn rabenseifner_moves_fewer_bytes_than_recursive_doubling() {
        // The whole point of the composition: 2·n·(p'−1)/p' elements per
        // rank instead of recursive doubling's n·log2(p).
        let topo = Topology::regions(4, 4);
        let m = crate::model::MachineParams::lassen();
        let n = 64usize;
        let rd = CommWorld::run(&topo, crate::comm::Timing::Virtual(m.clone()), |c| {
            allreduce_recursive_doubling(c, &contribution(c.rank(), n)).unwrap();
        });
        let rab = CommWorld::run(&topo, crate::comm::Timing::Virtual(m), |c| {
            allreduce_rabenseifner(c, &contribution(c.rank(), n)).unwrap();
        });
        let total = |t: &crate::trace::TraceSummary| t.total_bytes();
        assert!(
            total(&rab.trace) < total(&rd.trace),
            "rabenseifner {} B !< recursive-doubling {} B",
            total(&rab.trace),
            total(&rd.trace)
        );
    }

    #[test]
    fn alignment_predicate() {
        assert!(locality_rounds_align(16, 4)); // 4^2
        assert!(locality_rounds_align(8, 4)); // 1,4 | 8
        assert!(locality_rounds_align(12, 4)); // 1,4 | 12
        assert!(locality_rounds_align(3, 8)); // single round
        assert!(!locality_rounds_align(6, 4)); // 4 ∤ 6
        assert!(!locality_rounds_align(10, 3)); // 3 ∤ 10
        assert!(!locality_rounds_align(4, 1));
    }

    #[test]
    fn preconditions_surface_at_plan_time() {
        // Non-power-of-two p rejects when PLANNING, before any message.
        let topo = Topology::regions(3, 1);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let r = AllreduceRegistry::<u64>::standard();
            let err = r.plan_uniform("recursive-doubling", c, Shape::elems(2)).unwrap_err();
            err.to_string()
        });
        for msg in &run.results {
            assert!(msg.contains("power-of-two"), "{msg}");
        }
        let total: u64 = run.trace.per_rank.iter().map(|t| t.total_msgs()).sum();
        assert_eq!(total, 0, "plan-time rejection must send no messages");
        // ... but the zero-length plan bypasses the precondition uniformly.
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let r = AllreduceRegistry::<u64>::standard();
            let mut plan = r.plan_uniform("recursive-doubling", c, Shape::elems(0)).unwrap();
            let mut out: Vec<u64> = Vec::new();
            plan.execute(&[], &mut out).unwrap();
            out.is_empty()
        });
        assert!(run.results.iter().all(|&ok| ok));
    }

    #[test]
    fn unaligned_shapes_fall_back_and_stay_correct() {
        // 8 regions x 4 ppr is aligned; exercises p = 32.
        let topo = Topology::regions(8, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allreduce_locality_aware(c, &contribution(c.rank(), 3)).unwrap()
        });
        for r in &run.results {
            assert_eq!(r, &expected_sum(32, 3));
        }
        // 6 regions x 4 ppr is unaligned → recursive-doubling fallback,
        // and p = 24 is not a power of two: surfaced cleanly at plan time.
        let topo = Topology::regions(6, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allreduce_locality_aware(c, &contribution(c.rank(), 1)).is_err()
        });
        assert!(run.results.iter().all(|&e| e));
    }

    #[test]
    fn single_region_pure_local() {
        let topo = Topology::regions(1, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allreduce_locality_aware(c, &contribution(c.rank(), 2)).unwrap()
        });
        for r in &run.results {
            assert_eq!(r, &expected_sum(4, 2));
        }
    }

    #[test]
    fn plan_reuse_with_shifting_inputs() {
        let topo = Topology::regions(4, 4);
        let p = topo.size();
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let r = AllreduceRegistry::<u64>::standard();
            for name in r.names() {
                let mut plan = r.plan_uniform(name, c, Shape::elems(3)).unwrap();
                assert_eq!(plan.algorithm(), name);
                assert_eq!(plan.comm_size(), p);
                let mut out = vec![0u64; 3];
                for round in 0..5u64 {
                    let mine: Vec<u64> =
                        contribution(c.rank(), 3).iter().map(|v| v + round).collect();
                    plan.execute(&mine, &mut out).unwrap();
                    let expect: Vec<u64> = expected_sum(p, 3)
                        .iter()
                        .map(|v| v + round * p as u64)
                        .collect();
                    assert_eq!(out, expect, "{name} round {round}");
                }
            }
            true
        });
        assert!(run.results.iter().all(|&ok| ok));
    }
}
