//! Locality-aware allreduce — the paper's §6 future-work extension.
//!
//! “Locality-awareness can be extended to other collectives, removing
//! duplicate non-local messages for small data sizes …” We implement the
//! natural transfer of Algorithm 2's structure to a sum-allreduce and
//! compare it against standard recursive-doubling allreduce:
//!
//! * **standard**: recursive-doubling allreduce — `log2(p)` exchanges of
//!   the full vector, most of them non-local;
//! * **locality-aware**: reduce within each region (local allreduce), one
//!   exchange-and-reduce round among regions in which local rank `ℓ`
//!   pairs with region `g ± ℓ·pℓ^i` (local rank 0 idles), then a final
//!   local combine — `⌈log_pℓ(r)⌉` non-local messages per rank.

use super::grouping::{group_ranks, require_uniform, GroupBy};
use crate::comm::{Comm, Pod};
use crate::error::Result;

/// Element types that can be summed (the reduction used by the paper's
/// allreduce reference [4]).
pub trait Summable: Pod + std::ops::Add<Output = Self> {}
impl Summable for u32 {}
impl Summable for u64 {}
impl Summable for i32 {}
impl Summable for i64 {}
impl Summable for f32 {}
impl Summable for f64 {}

fn add_into<T: Summable>(acc: &mut [T], x: &[T]) {
    debug_assert_eq!(acc.len(), x.len());
    for (a, b) in acc.iter_mut().zip(x) {
        *a = *a + *b;
    }
}

/// Standard recursive-doubling allreduce (requires power-of-two size).
pub fn allreduce_recursive_doubling<T: Summable>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    let p = comm.size();
    let id = comm.rank();
    if !p.is_power_of_two() {
        return Err(crate::error::Error::Precondition(format!(
            "recursive-doubling allreduce requires power-of-two size, got {p}"
        )));
    }
    let tag = comm.next_coll_tag();
    let mut acc = local.to_vec();
    let mut dist = 1usize;
    let mut step = 0u64;
    while dist < p {
        let peer = id ^ dist;
        let _req = comm.isend(&acc, peer, tag + step)?;
        let got: Vec<T> = comm.irecv(peer, tag + step).wait(comm)?;
        add_into(&mut acc, &got);
        dist <<= 1;
        step += 1;
    }
    Ok(acc)
}

/// True if Algorithm 2's round structure sums every region exactly once
/// for `r_n` regions of `ppr` ranks: every round width `ppr^i < r_n` must
/// divide `r_n`, otherwise the wrap-around groups of the allgather (which
/// are idempotent there) would double-count partial sums here.
pub fn locality_rounds_align(r_n: usize, ppr: usize) -> bool {
    if ppr < 2 {
        return false;
    }
    let mut w = 1usize;
    while w < r_n {
        if r_n % w != 0 {
            return false;
        }
        w = w.saturating_mul(ppr);
    }
    true
}

/// Locality-aware allreduce: local allreduce, `⌈log_pℓ(r)⌉` sparse
/// non-local exchange rounds (local rank 0 idles), each followed by a
/// local combine of the received partial sums.
///
/// Unlike the allgather — where wrap-around duplicate coverage is benign —
/// summation is not idempotent, so the non-local rounds require aligned
/// groups ([`locality_rounds_align`]); other shapes fall back to standard
/// recursive doubling.
pub fn allreduce_locality_aware<T: Summable>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    let groups = group_ranks(comm, GroupBy::Region)?;
    let ppr = require_uniform(&groups, "locality-aware allreduce")?;
    let r_n = groups.count();
    if r_n == 1 {
        let lc = comm.sub(&groups.members[groups.mine])?;
        return allreduce_recursive_doubling(&lc, local);
    }
    if ppr == 1 || !locality_rounds_align(r_n, ppr) {
        return allreduce_recursive_doubling(comm, local);
    }
    let g = groups.mine;
    let l = groups.my_local;
    let local_comm = comm.sub(&groups.members[g])?;

    // Phase 1: local allreduce → every rank holds its region's sum.
    let mut acc = allreduce_recursive_doubling(&local_comm, local)?;

    // Phase 2: non-local rounds. Invariant: every rank of region g holds
    // the exact sum over regions [g, g+width) mod r_n. Local rank j ≥ 1
    // fetches the disjoint group [g + j·width, g + (j+1)·width); alignment
    // (checked above) guarantees no group wraps into already-held regions.
    let mut width = 1usize;
    while width < r_n {
        let tag = comm.next_coll_tag();
        let blocks = (r_n / width).min(ppr); // groups reachable this round
        let active = |j: usize| j > 0 && j < blocks;
        let mut mine: Vec<T> = Vec::new();
        if active(l) {
            let dist = (l * width) % r_n;
            let dst = groups.members[(g + r_n - dist) % r_n][l];
            let src = groups.members[(g + dist) % r_n][l];
            let _req = comm.isend(&acc, dst, tag)?;
            mine = comm.irecv(src, tag).wait(comm)?;
        }
        // Local combine: gather the partials every active rank received and
        // sum them all — each covers a distinct aligned group of regions.
        let counts: Vec<usize> = (0..ppr)
            .map(|j| if active(j) { acc.len() } else { 0 })
            .collect();
        let gathered = super::primitives::allgatherv(&local_comm, &mine, &counts)?;
        for part in gathered.chunks_exact(acc.len().max(1)) {
            add_into(&mut acc, part);
        }
        width = width.saturating_mul(ppr);
    }
    Ok(acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CommWorld, Timing};
    use crate::topology::Topology;

    fn expected_sum(p: usize, n: usize) -> Vec<u64> {
        // rank r contributes [r, r+1, ..]: sum over r of (r + j)
        (0..n)
            .map(|j| (0..p).map(|r| (r + j) as u64).sum())
            .collect()
    }

    fn contribution(rank: usize, n: usize) -> Vec<u64> {
        (0..n).map(|j| (rank + j) as u64).collect()
    }

    #[test]
    fn recursive_doubling_sums() {
        let topo = Topology::regions(2, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allreduce_recursive_doubling(c, &contribution(c.rank(), 3)).unwrap()
        });
        for r in &run.results {
            assert_eq!(r, &expected_sum(8, 3));
        }
    }

    #[test]
    fn locality_aware_matches_recursive_doubling() {
        for (regions, ppr) in [(4usize, 4usize), (2, 2), (16, 4), (4, 8)] {
            let topo = Topology::regions(regions, ppr);
            let p = topo.size();
            let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                allreduce_locality_aware(c, &contribution(c.rank(), 2)).unwrap()
            });
            for r in &run.results {
                assert_eq!(r, &expected_sum(p, 2), "regions={regions} ppr={ppr}");
            }
        }
    }

    #[test]
    fn locality_aware_fewer_nonlocal_messages() {
        let topo = Topology::regions(16, 4); // p = 64
        let std = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allreduce_recursive_doubling(c, &contribution(c.rank(), 4)).unwrap();
        });
        let loc = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allreduce_locality_aware(c, &contribution(c.rank(), 4)).unwrap();
        });
        assert!(
            loc.trace.max_nonlocal_msgs() < std.trace.max_nonlocal_msgs(),
            "loc {} vs std {}",
            loc.trace.max_nonlocal_msgs(),
            std.trace.max_nonlocal_msgs()
        );
    }

    #[test]
    fn alignment_predicate() {
        assert!(locality_rounds_align(16, 4)); // 4^2
        assert!(locality_rounds_align(8, 4)); // 1,4 | 8
        assert!(locality_rounds_align(12, 4)); // 1,4 | 12
        assert!(locality_rounds_align(3, 8)); // single round
        assert!(!locality_rounds_align(6, 4)); // 4 ∤ 6
        assert!(!locality_rounds_align(10, 3)); // 3 ∤ 10
        assert!(!locality_rounds_align(4, 1));
    }

    #[test]
    fn unaligned_shapes_fall_back_and_stay_correct() {
        // 6 regions × 4 ppr is unaligned -> recursive-doubling fallback
        // still sums correctly (p = 24 is not a power of two... use 8x4).
        let topo = Topology::regions(8, 4); // aligned, but exercise p=32
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allreduce_locality_aware(c, &contribution(c.rank(), 3)).unwrap()
        });
        for r in &run.results {
            assert_eq!(r, &expected_sum(32, 3));
        }
        // genuinely unaligned: 2 regions of 16 with... 6 regions needs
        // power-of-two total for the fallback: 16 regions of 2, width run
        // 1,2,4,8 all divide 16 -> aligned; use (8,2): aligned too. For a
        // true fallback case take ppr=4, r=8? aligned. r=6,ppr=4 -> p=24
        // not power of two, fallback errors; assert that surfaces cleanly.
        let topo = Topology::regions(6, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allreduce_locality_aware(c, &contribution(c.rank(), 1)).is_err()
        });
        assert!(run.results.iter().all(|&e| e));
    }

    #[test]
    fn single_region_pure_local() {
        let topo = Topology::regions(1, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allreduce_locality_aware(c, &contribution(c.rank(), 2)).unwrap()
        });
        for r in &run.results {
            assert_eq!(r, &expected_sum(4, 2));
        }
    }
}
