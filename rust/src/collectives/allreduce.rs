//! Locality-aware allreduce — the paper's §6 future-work extension — as
//! schedule builders.
//!
//! “Locality-awareness can be extended to other collectives, removing
//! duplicate non-local messages for small data sizes …” We implement the
//! natural transfer of Algorithm 2's structure to a sum-allreduce and
//! compare it against standard recursive-doubling allreduce:
//!
//! * **`recursive-doubling`**: `log2(p)` exchanges of the full vector,
//!   most of them non-local (requires power-of-two `p`, checked at plan
//!   time);
//! * **`loc-aware`**: reduce within each region (local allreduce), then
//!   `⌈log_pℓ(r)⌉` exchange-and-reduce rounds among regions in which local
//!   rank `ℓ` pairs with region `g ± ℓ·pℓ^i` (local rank 0 idles), each
//!   closed by a local allgatherv + combine — `⌈log_pℓ(r)⌉` non-local
//!   messages per rank.
//!
//! Both build [`Schedule`]s whose reductions are explicit
//! [`Step::Reduce`](super::schedule::Step) steps, executed by the one
//! generic interpreter with the [`Summable`] reducer — groups, round
//! schedules, tag blocks and scratch are all schedule data; `execute` is
//! pure communication + summation with zero allocation and no tag
//! consumption. Shape preconditions (power-of-two sizes, uniform groups)
//! surface at `plan()` time; `n == 0` plans are uniform no-ops.

use super::grouping::GroupBy;
use super::plan::{
    trivial_reduce_plan, AllreduceAlgorithm, AllreducePlan, NamedAlgorithm, OpKind, Shape,
};
use super::schedule::{
    emit_group_allgatherv, emit_group_rd_allreduce, locate, uniform_size, SchedPlan, Schedule,
    ScheduleBuilder, Slice, WorldView,
};
use crate::comm::Comm;
use crate::error::Result;

/// Element types that can be summed (re-exported from the plan framework;
/// the reduction used by the paper's allreduce reference [4]).
pub use super::plan::Summable;

/// Standard recursive-doubling allreduce (registry entry).
pub struct RecursiveDoublingAllreduce;

impl NamedAlgorithm for RecursiveDoublingAllreduce {
    fn name(&self) -> &'static str {
        "recursive-doubling"
    }

    fn summary(&self) -> &'static str {
        "recursive-doubling allreduce: log2(p) full-vector exchanges, power-of-two p only"
    }
}

impl<T: Summable> AllreduceAlgorithm<T> for RecursiveDoublingAllreduce {
    fn plan(&self, comm: &Comm, shape: Shape) -> Result<Box<dyn AllreducePlan<T>>> {
        if let Some(p) = trivial_reduce_plan("recursive-doubling", comm, shape) {
            return Ok(p);
        }
        let sched =
            build_rd_schedule(comm.size(), comm.rank(), shape.n, std::mem::size_of::<T>())?;
        Ok(SchedPlan::<T>::boxed(comm, "recursive-doubling", sched)?)
    }
}

/// Build the recursive-doubling allreduce schedule for one rank (pure;
/// SPMD). Errors on non-power-of-two communicators.
pub fn build_rd_schedule(
    p: usize,
    rank: usize,
    n: usize,
    elem_bytes: usize,
) -> Result<Schedule> {
    let mut sb = ScheduleBuilder::new("recursive doubling");
    sb.copy(Slice::input(0, n), Slice::output(0, n));
    let members: Vec<usize> = (0..p).collect();
    emit_group_rd_allreduce(&mut sb, &members, rank, n)?;
    Ok(sb.finish(OpKind::Allreduce, p, n, elem_bytes, "recursive-doubling"))
}

/// True if Algorithm 2's round structure sums every region exactly once
/// for `r_n` regions of `ppr` ranks: every round width `ppr^i < r_n` must
/// divide `r_n`, otherwise the wrap-around groups of the allgather (which
/// are idempotent there) would double-count partial sums here.
pub fn locality_rounds_align(r_n: usize, ppr: usize) -> bool {
    if ppr < 2 {
        return false;
    }
    let mut w = 1usize;
    while w < r_n {
        if r_n % w != 0 {
            return false;
        }
        w = w.saturating_mul(ppr);
    }
    true
}

/// The locality-aware regional allreduce (registry entry).
pub struct LocalityAwareAllreduce;

impl NamedAlgorithm for LocalityAwareAllreduce {
    fn name(&self) -> &'static str {
        "loc-aware"
    }

    fn summary(&self) -> &'static str {
        "regional allreduce (§6): local reduce, log_ppr(r) sparse non-local rounds"
    }
}

impl<T: Summable> AllreduceAlgorithm<T> for LocalityAwareAllreduce {
    fn plan(&self, comm: &Comm, shape: Shape) -> Result<Box<dyn AllreducePlan<T>>> {
        if let Some(p) = trivial_reduce_plan("loc-aware", comm, shape) {
            return Ok(p);
        }
        let view = WorldView::from_comm(comm);
        let sched = build_loc_schedule(&view, comm.rank(), shape.n, std::mem::size_of::<T>())?;
        Ok(SchedPlan::<T>::boxed(comm, "loc-aware", sched)?)
    }
}

/// Build the locality-aware allreduce schedule for one rank (pure; SPMD).
///
/// Summation is not idempotent, so the non-local rounds require aligned
/// groups ([`locality_rounds_align`]); single-region, single-rank-per-
/// region and unaligned shapes fall back to a recursive-doubling schedule
/// (whose power-of-two precondition then also surfaces at plan time).
pub fn build_loc_schedule(
    view: &WorldView,
    rank: usize,
    n: usize,
    elem_bytes: usize,
) -> Result<Schedule> {
    let all: Vec<usize> = (0..view.p).collect();
    let groups = view.split(&all, GroupBy::Region);
    let ppr = uniform_size(&groups, "locality-aware allreduce")?;
    let r_n = groups.len();
    if r_n == 1 || ppr == 1 || !locality_rounds_align(r_n, ppr) {
        let mut sched = build_rd_schedule(view.p, rank, n, elem_bytes)?;
        sched.label = "loc-aware[recursive-doubling]".to_string();
        return Ok(sched);
    }
    let (g, l) = locate(&groups, rank)?;

    let mut sb = ScheduleBuilder::new("local allreduce");
    // Phase 1: allreduce within the region → every rank holds its region's
    // sum (plan-time error if ppr is not a power of two).
    sb.copy(Slice::input(0, n), Slice::output(0, n));
    emit_group_rd_allreduce(&mut sb, &groups[g], rank, n)?;

    // Invariant per round: every rank of region g holds the exact sum over
    // regions [g, g+width) mod r_n. Local rank j ≥ 1 fetches the disjoint
    // group [g + j·width, g + (j+1)·width); alignment (checked above)
    // guarantees no group wraps into held regions.
    let mut width = 1usize;
    let mut round_no = 1usize;
    while width < r_n {
        sb.round(format!("non-local round {round_no}"));
        let tag = sb.tag();
        let blocks = (r_n / width).min(ppr); // groups reachable this round
        let active_j = |j: usize| j > 0 && j < blocks;
        let active = active_j(l);
        let recv = if active { Some(sb.scratch(n)) } else { None };
        if let Some(rbuf) = recv {
            let dist = (l * width) % r_n;
            let to = groups[(g + r_n - dist) % r_n][l];
            let from = groups[(g + dist) % r_n][l];
            sb.sendrecv(to, Slice::output(0, n), from, Slice::at(rbuf, 0, n), tag, 0);
        }
        // Local allgatherv of the received partials, then combine.
        let counts: Vec<usize> = (0..ppr).map(|j| if active_j(j) { n } else { 0 }).collect();
        let total: usize = counts.iter().sum();
        let gathered = sb.scratch(total);
        let contrib = match recv {
            Some(rbuf) => Slice::at(rbuf, 0, n),
            None => Slice::input(0, 0),
        };
        emit_group_allgatherv(
            &mut sb,
            &groups[g],
            rank,
            &counts,
            contrib,
            Slice::at(gathered, 0, total),
        );
        for c in 0..total / n {
            sb.reduce(Slice::at(gathered, c * n, n), Slice::output(0, n));
        }
        width = width.saturating_mul(ppr);
        round_no += 1;
    }
    Ok(sb.finish(OpKind::Allreduce, view.p, n, elem_bytes, "loc-aware"))
}

/// One-shot standard recursive-doubling allreduce: plan + single execute
/// (requires power-of-two size, surfaced before any communication).
pub fn allreduce_recursive_doubling<T: Summable>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot_reduce(&RecursiveDoublingAllreduce, comm, local)
}

/// One-shot locality-aware allreduce: plan + single execute. Unaligned or
/// locality-free shapes fall back to recursive doubling.
pub fn allreduce_locality_aware<T: Summable>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot_reduce(&LocalityAwareAllreduce, comm, local)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::plan::AllreduceRegistry;
    use crate::comm::{CommWorld, Timing};
    use crate::topology::Topology;

    fn expected_sum(p: usize, n: usize) -> Vec<u64> {
        // rank r contributes [r, r+1, ..]: sum over r of (r + j)
        (0..n)
            .map(|j| (0..p).map(|r| (r + j) as u64).sum())
            .collect()
    }

    fn contribution(rank: usize, n: usize) -> Vec<u64> {
        (0..n).map(|j| (rank + j) as u64).collect()
    }

    #[test]
    fn recursive_doubling_sums() {
        let topo = Topology::regions(2, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allreduce_recursive_doubling(c, &contribution(c.rank(), 3)).unwrap()
        });
        for r in &run.results {
            assert_eq!(r, &expected_sum(8, 3));
        }
    }

    #[test]
    fn locality_aware_matches_recursive_doubling() {
        for (regions, ppr) in [(4usize, 4usize), (2, 2), (16, 4), (4, 8)] {
            let topo = Topology::regions(regions, ppr);
            let p = topo.size();
            let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                allreduce_locality_aware(c, &contribution(c.rank(), 2)).unwrap()
            });
            for r in &run.results {
                assert_eq!(r, &expected_sum(p, 2), "regions={regions} ppr={ppr}");
            }
        }
    }

    #[test]
    fn locality_aware_fewer_nonlocal_messages() {
        let topo = Topology::regions(16, 4); // p = 64
        let std = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allreduce_recursive_doubling(c, &contribution(c.rank(), 4)).unwrap();
        });
        let loc = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allreduce_locality_aware(c, &contribution(c.rank(), 4)).unwrap();
        });
        assert!(
            loc.trace.max_nonlocal_msgs() < std.trace.max_nonlocal_msgs(),
            "loc {} vs std {}",
            loc.trace.max_nonlocal_msgs(),
            std.trace.max_nonlocal_msgs()
        );
    }

    #[test]
    fn alignment_predicate() {
        assert!(locality_rounds_align(16, 4)); // 4^2
        assert!(locality_rounds_align(8, 4)); // 1,4 | 8
        assert!(locality_rounds_align(12, 4)); // 1,4 | 12
        assert!(locality_rounds_align(3, 8)); // single round
        assert!(!locality_rounds_align(6, 4)); // 4 ∤ 6
        assert!(!locality_rounds_align(10, 3)); // 3 ∤ 10
        assert!(!locality_rounds_align(4, 1));
    }

    #[test]
    fn preconditions_surface_at_plan_time() {
        // Non-power-of-two p rejects when PLANNING, before any message.
        let topo = Topology::regions(3, 1);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let r = AllreduceRegistry::<u64>::standard();
            let err = r.plan("recursive-doubling", c, Shape::elems(2)).unwrap_err();
            err.to_string()
        });
        for msg in &run.results {
            assert!(msg.contains("power-of-two"), "{msg}");
        }
        let total: u64 = run.trace.per_rank.iter().map(|t| t.total_msgs()).sum();
        assert_eq!(total, 0, "plan-time rejection must send no messages");
        // ... but the zero-length plan bypasses the precondition uniformly.
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let r = AllreduceRegistry::<u64>::standard();
            let mut plan = r.plan("recursive-doubling", c, Shape::elems(0)).unwrap();
            let mut out: Vec<u64> = Vec::new();
            plan.execute(&[], &mut out).unwrap();
            out.is_empty()
        });
        assert!(run.results.iter().all(|&ok| ok));
    }

    #[test]
    fn unaligned_shapes_fall_back_and_stay_correct() {
        // 8 regions x 4 ppr is aligned; exercises p = 32.
        let topo = Topology::regions(8, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allreduce_locality_aware(c, &contribution(c.rank(), 3)).unwrap()
        });
        for r in &run.results {
            assert_eq!(r, &expected_sum(32, 3));
        }
        // 6 regions x 4 ppr is unaligned → recursive-doubling fallback,
        // and p = 24 is not a power of two: surfaced cleanly at plan time.
        let topo = Topology::regions(6, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allreduce_locality_aware(c, &contribution(c.rank(), 1)).is_err()
        });
        assert!(run.results.iter().all(|&e| e));
    }

    #[test]
    fn single_region_pure_local() {
        let topo = Topology::regions(1, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allreduce_locality_aware(c, &contribution(c.rank(), 2)).unwrap()
        });
        for r in &run.results {
            assert_eq!(r, &expected_sum(4, 2));
        }
    }

    #[test]
    fn plan_reuse_with_shifting_inputs() {
        let topo = Topology::regions(4, 4);
        let p = topo.size();
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let r = AllreduceRegistry::<u64>::standard();
            for name in r.names() {
                let mut plan = r.plan(name, c, Shape::elems(3)).unwrap();
                assert_eq!(plan.algorithm(), name);
                assert_eq!(plan.comm_size(), p);
                let mut out = vec![0u64; 3];
                for round in 0..5u64 {
                    let mine: Vec<u64> =
                        contribution(c.rank(), 3).iter().map(|v| v + round).collect();
                    plan.execute(&mine, &mut out).unwrap();
                    let expect: Vec<u64> = expected_sum(p, 3)
                        .iter()
                        .map(|v| v + round * p as u64)
                        .collect();
                    assert_eq!(out, expect, "{name} round {round}");
                }
            }
            true
        });
        assert!(run.results.iter().all(|&ok| ok));
    }
}
