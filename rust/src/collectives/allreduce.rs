//! Locality-aware allreduce — the paper's §6 future-work extension, as
//! persistent plans.
//!
//! “Locality-awareness can be extended to other collectives, removing
//! duplicate non-local messages for small data sizes …” We implement the
//! natural transfer of Algorithm 2's structure to a sum-allreduce and
//! compare it against standard recursive-doubling allreduce:
//!
//! * **`recursive-doubling`**: `log2(p)` exchanges of the full vector,
//!   most of them non-local (requires power-of-two `p`, checked at plan
//!   time);
//! * **`loc-aware`**: reduce within each region (local allreduce), then
//!   `⌈log_pℓ(r)⌉` exchange-and-reduce rounds among regions in which local
//!   rank `ℓ` pairs with region `g ± ℓ·pℓ^i` (local rank 0 idles), each
//!   closed by a local allgatherv + combine — `⌈log_pℓ(r)⌉` non-local
//!   messages per rank.
//!
//! Both are [`AllreducePlan`] factories registered in
//! [`super::plan::AllreduceRegistry`]: groups, sub-communicators, round
//! schedules, tag blocks and scratch are built once at plan time;
//! `execute` is pure communication + summation with zero allocation and no
//! tag consumption. Shape preconditions (power-of-two sizes, uniform
//! groups) surface at `plan()` time; `n == 0` plans are uniform no-ops.

use super::grouping::{group_ranks, require_uniform, GroupBy};
use super::plan::{
    check_reduce_io, trivial_reduce_plan, AllreduceAlgorithm, AllreducePlan, CollectivePlan,
    NamedAlgorithm, PlanCore, SelectedPlan, Shape,
};
use super::primitives::AllgathervPlan;
use crate::comm::Comm;
use crate::error::Result;

/// Element types that can be summed (re-exported from the plan framework;
/// the reduction used by the paper's allreduce reference [4]).
pub use super::plan::Summable;

fn add_into<T: Summable>(acc: &mut [T], x: &[T]) {
    debug_assert_eq!(acc.len(), x.len());
    for (a, b) in acc.iter_mut().zip(x) {
        *a = *a + *b;
    }
}

/// Standard recursive-doubling allreduce (registry entry).
pub struct RecursiveDoublingAllreduce;

impl NamedAlgorithm for RecursiveDoublingAllreduce {
    fn name(&self) -> &'static str {
        "recursive-doubling"
    }

    fn summary(&self) -> &'static str {
        "recursive-doubling allreduce: log2(p) full-vector exchanges, power-of-two p only"
    }
}

impl<T: Summable> AllreduceAlgorithm<T> for RecursiveDoublingAllreduce {
    fn plan(&self, comm: &Comm, shape: Shape) -> Result<Box<dyn AllreducePlan<T>>> {
        if let Some(p) = trivial_reduce_plan("recursive-doubling", comm, shape) {
            return Ok(p);
        }
        Ok(Box::new(RecursiveDoublingAllreducePlan::<T>::new(comm, shape.n)?))
    }
}

/// Persistent recursive-doubling allreduce plan: XOR peer schedule, one
/// tag per step, one `n`-element receive scratch.
pub struct RecursiveDoublingAllreducePlan<T: Summable> {
    core: PlanCore,
    /// XOR exchange peers, one per step.
    peers: Vec<usize>,
    /// Receive scratch, length `n`.
    recv: Vec<T>,
}

impl<T: Summable> RecursiveDoublingAllreducePlan<T> {
    /// Collectively plan the exchange schedule. Errors at plan time on
    /// non-power-of-two communicators.
    pub fn new(comm: &Comm, n: usize) -> Result<RecursiveDoublingAllreducePlan<T>> {
        let p = comm.size();
        if !p.is_power_of_two() {
            return Err(crate::error::Error::Precondition(format!(
                "recursive-doubling allreduce requires power-of-two size, got {p}"
            )));
        }
        let id = comm.rank();
        let mut peers = Vec::new();
        let mut dist = 1usize;
        while dist < p {
            peers.push(id ^ dist);
            dist <<= 1;
        }
        Ok(RecursiveDoublingAllreducePlan {
            core: PlanCore::new(comm, n, peers.len() as u64),
            peers,
            recv: vec![T::default(); n],
        })
    }
}

impl<T: Summable> CollectivePlan for RecursiveDoublingAllreducePlan<T> {
    fn algorithm(&self) -> &'static str {
        "recursive-doubling"
    }

    fn shape(&self) -> Shape {
        Shape { n: self.core.n }
    }

    fn comm_size(&self) -> usize {
        self.core.p
    }
}

impl<T: Summable> AllreducePlan<T> for RecursiveDoublingAllreducePlan<T> {
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()> {
        let core = &self.core;
        check_reduce_io(core.n, input, output)?;
        if core.n == 0 {
            return Ok(());
        }
        output.copy_from_slice(input);
        for (i, &peer) in self.peers.iter().enumerate() {
            let tag = core.tag(i as u64);
            let _req = core.comm.isend(output, peer, tag)?;
            core.comm.recv_into(peer, tag, &mut self.recv)?;
            add_into(output, &self.recv);
        }
        Ok(())
    }
}

/// True if Algorithm 2's round structure sums every region exactly once
/// for `r_n` regions of `ppr` ranks: every round width `ppr^i < r_n` must
/// divide `r_n`, otherwise the wrap-around groups of the allgather (which
/// are idempotent there) would double-count partial sums here.
pub fn locality_rounds_align(r_n: usize, ppr: usize) -> bool {
    if ppr < 2 {
        return false;
    }
    let mut w = 1usize;
    while w < r_n {
        if r_n % w != 0 {
            return false;
        }
        w = w.saturating_mul(ppr);
    }
    true
}

/// The locality-aware regional allreduce (registry entry).
pub struct LocalityAwareAllreduce;

impl NamedAlgorithm for LocalityAwareAllreduce {
    fn name(&self) -> &'static str {
        "loc-aware"
    }

    fn summary(&self) -> &'static str {
        "regional allreduce (§6): local reduce, log_ppr(r) sparse non-local rounds"
    }
}

impl<T: Summable> AllreduceAlgorithm<T> for LocalityAwareAllreduce {
    fn plan(&self, comm: &Comm, shape: Shape) -> Result<Box<dyn AllreducePlan<T>>> {
        if let Some(p) = trivial_reduce_plan("loc-aware", comm, shape) {
            return Ok(p);
        }
        LocalityAwareAllreducePlan::<T>::plan_boxed(comm, shape.n)
    }
}

/// One non-local exchange-and-combine round of the locality-aware plan.
struct Round<T: Summable> {
    /// Whether this rank exchanges non-locally this round.
    active: bool,
    /// Exchange peers in parent-communicator ranks (valid when `active`).
    dst: usize,
    src: usize,
    /// Local allgatherv of the received partial sums (counts fixed at
    /// plan time: `n` for each active local rank, 0 otherwise).
    vplan: AllgathervPlan<T>,
    /// Non-local receive scratch, length `n` when active.
    recv: Vec<T>,
    /// Local-gather output, one `n`-chunk per active local rank.
    gathered: Vec<T>,
}

/// Persistent locality-aware allreduce plan (see module docs).
///
/// Summation is not idempotent, so the non-local rounds require aligned
/// groups ([`locality_rounds_align`]); single-region, single-rank-per-
/// region and unaligned shapes fall back to a recursive-doubling plan
/// (whose power-of-two precondition then also surfaces at plan time).
pub struct LocalityAwareAllreducePlan<T: Summable> {
    /// Parent communicator + one exchange tag per round.
    core: PlanCore,
    /// Phase 1: allreduce within the region (over the retained sub-comm).
    phase1: RecursiveDoublingAllreducePlan<T>,
    rounds: Vec<Round<T>>,
}

impl<T: Summable> LocalityAwareAllreducePlan<T> {
    /// Collectively plan over `comm`, falling back to recursive doubling
    /// when the topology offers no exploitable (aligned) locality.
    pub fn plan_boxed(comm: &Comm, n: usize) -> Result<Box<dyn AllreducePlan<T>>> {
        let groups = group_ranks(comm, GroupBy::Region)?;
        let ppr = require_uniform(&groups, "locality-aware allreduce")?;
        let r_n = groups.count();
        if r_n == 1 || ppr == 1 || !locality_rounds_align(r_n, ppr) {
            return Ok(Box::new(SelectedPlan {
                name: "loc-aware",
                inner: Box::new(RecursiveDoublingAllreducePlan::<T>::new(comm, n)?)
                    as Box<dyn AllreducePlan<T>>,
            }));
        }
        let g = groups.mine;
        let l = groups.my_local;
        let local_comm = comm.sub(&groups.members[g])?;
        // Phase 1 plans on the local communicator (its own tag space);
        // plan-time error if ppr is not a power of two.
        let phase1 = RecursiveDoublingAllreducePlan::<T>::new(&local_comm, n)?;

        // Count the rounds first so the parent tag block is one reservation.
        let mut n_rounds = 0u64;
        let mut width = 1usize;
        while width < r_n {
            n_rounds += 1;
            width = width.saturating_mul(ppr);
        }
        let core = PlanCore::new(comm, n, n_rounds);

        // Invariant per round: every rank of region g holds the exact sum
        // over regions [g, g+width) mod r_n. Local rank j ≥ 1 fetches the
        // disjoint group [g + j·width, g + (j+1)·width); alignment
        // (checked above) guarantees no group wraps into held regions.
        let mut rounds = Vec::new();
        let mut width = 1usize;
        while width < r_n {
            let blocks = (r_n / width).min(ppr); // groups reachable this round
            let active_j = |j: usize| j > 0 && j < blocks;
            let active = active_j(l);
            let (dst, src) = if active {
                let dist = (l * width) % r_n;
                (
                    groups.members[(g + r_n - dist) % r_n][l],
                    groups.members[(g + dist) % r_n][l],
                )
            } else {
                (0, 0)
            };
            let counts: Vec<usize> =
                (0..ppr).map(|j| if active_j(j) { n } else { 0 }).collect();
            let total: usize = counts.iter().sum();
            let vplan = AllgathervPlan::<T>::new(&local_comm, &counts)?;
            rounds.push(Round {
                active,
                dst,
                src,
                vplan,
                recv: vec![T::default(); if active { n } else { 0 }],
                gathered: vec![T::default(); total],
            });
            width = width.saturating_mul(ppr);
        }
        Ok(Box::new(LocalityAwareAllreducePlan { core, phase1, rounds }))
    }
}

impl<T: Summable> CollectivePlan for LocalityAwareAllreducePlan<T> {
    fn algorithm(&self) -> &'static str {
        "loc-aware"
    }

    fn shape(&self) -> Shape {
        Shape { n: self.core.n }
    }

    fn comm_size(&self) -> usize {
        self.core.p
    }
}

impl<T: Summable> AllreducePlan<T> for LocalityAwareAllreducePlan<T> {
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()> {
        let core = &self.core;
        check_reduce_io(core.n, input, output)?;
        let n = core.n;
        if n == 0 {
            return Ok(());
        }
        // Phase 1: local allreduce → every rank holds its region's sum.
        self.phase1.execute(input, output)?;
        // Phase 2: sparse non-local rounds, each closed by a local
        // allgatherv of the received partials + combine.
        for (i, round) in self.rounds.iter_mut().enumerate() {
            if round.active {
                let tag = core.tag(i as u64);
                let _req = core.comm.isend(output, round.dst, tag)?;
                core.comm.recv_into(round.src, tag, &mut round.recv)?;
            }
            round.vplan.execute(&round.recv, &mut round.gathered)?;
            for part in round.gathered.chunks_exact(n) {
                add_into(output, part);
            }
        }
        Ok(())
    }
}

/// One-shot standard recursive-doubling allreduce: plan + single execute
/// (requires power-of-two size, surfaced before any communication).
pub fn allreduce_recursive_doubling<T: Summable>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot_reduce(&RecursiveDoublingAllreduce, comm, local)
}

/// One-shot locality-aware allreduce: plan + single execute. Unaligned or
/// locality-free shapes fall back to recursive doubling.
pub fn allreduce_locality_aware<T: Summable>(comm: &Comm, local: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot_reduce(&LocalityAwareAllreduce, comm, local)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::plan::AllreduceRegistry;
    use crate::comm::{CommWorld, Timing};
    use crate::topology::Topology;

    fn expected_sum(p: usize, n: usize) -> Vec<u64> {
        // rank r contributes [r, r+1, ..]: sum over r of (r + j)
        (0..n)
            .map(|j| (0..p).map(|r| (r + j) as u64).sum())
            .collect()
    }

    fn contribution(rank: usize, n: usize) -> Vec<u64> {
        (0..n).map(|j| (rank + j) as u64).collect()
    }

    #[test]
    fn recursive_doubling_sums() {
        let topo = Topology::regions(2, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allreduce_recursive_doubling(c, &contribution(c.rank(), 3)).unwrap()
        });
        for r in &run.results {
            assert_eq!(r, &expected_sum(8, 3));
        }
    }

    #[test]
    fn locality_aware_matches_recursive_doubling() {
        for (regions, ppr) in [(4usize, 4usize), (2, 2), (16, 4), (4, 8)] {
            let topo = Topology::regions(regions, ppr);
            let p = topo.size();
            let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
                allreduce_locality_aware(c, &contribution(c.rank(), 2)).unwrap()
            });
            for r in &run.results {
                assert_eq!(r, &expected_sum(p, 2), "regions={regions} ppr={ppr}");
            }
        }
    }

    #[test]
    fn locality_aware_fewer_nonlocal_messages() {
        let topo = Topology::regions(16, 4); // p = 64
        let std = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allreduce_recursive_doubling(c, &contribution(c.rank(), 4)).unwrap();
        });
        let loc = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allreduce_locality_aware(c, &contribution(c.rank(), 4)).unwrap();
        });
        assert!(
            loc.trace.max_nonlocal_msgs() < std.trace.max_nonlocal_msgs(),
            "loc {} vs std {}",
            loc.trace.max_nonlocal_msgs(),
            std.trace.max_nonlocal_msgs()
        );
    }

    #[test]
    fn alignment_predicate() {
        assert!(locality_rounds_align(16, 4)); // 4^2
        assert!(locality_rounds_align(8, 4)); // 1,4 | 8
        assert!(locality_rounds_align(12, 4)); // 1,4 | 12
        assert!(locality_rounds_align(3, 8)); // single round
        assert!(!locality_rounds_align(6, 4)); // 4 ∤ 6
        assert!(!locality_rounds_align(10, 3)); // 3 ∤ 10
        assert!(!locality_rounds_align(4, 1));
    }

    #[test]
    fn preconditions_surface_at_plan_time() {
        // Non-power-of-two p rejects when PLANNING, before any message.
        let topo = Topology::regions(3, 1);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let r = AllreduceRegistry::<u64>::standard();
            let err = r.plan("recursive-doubling", c, Shape::elems(2)).unwrap_err();
            err.to_string()
        });
        for msg in &run.results {
            assert!(msg.contains("power-of-two"), "{msg}");
        }
        let total: u64 = run.trace.per_rank.iter().map(|t| t.total_msgs()).sum();
        assert_eq!(total, 0, "plan-time rejection must send no messages");
        // ... but the zero-length plan bypasses the precondition uniformly.
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let r = AllreduceRegistry::<u64>::standard();
            let mut plan = r.plan("recursive-doubling", c, Shape::elems(0)).unwrap();
            let mut out: Vec<u64> = Vec::new();
            plan.execute(&[], &mut out).unwrap();
            out.is_empty()
        });
        assert!(run.results.iter().all(|&ok| ok));
    }

    #[test]
    fn unaligned_shapes_fall_back_and_stay_correct() {
        // 8 regions x 4 ppr is aligned; exercises p = 32.
        let topo = Topology::regions(8, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allreduce_locality_aware(c, &contribution(c.rank(), 3)).unwrap()
        });
        for r in &run.results {
            assert_eq!(r, &expected_sum(32, 3));
        }
        // 6 regions x 4 ppr is unaligned → recursive-doubling fallback,
        // and p = 24 is not a power of two: surfaced cleanly at plan time.
        let topo = Topology::regions(6, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allreduce_locality_aware(c, &contribution(c.rank(), 1)).is_err()
        });
        assert!(run.results.iter().all(|&e| e));
    }

    #[test]
    fn single_region_pure_local() {
        let topo = Topology::regions(1, 4);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            allreduce_locality_aware(c, &contribution(c.rank(), 2)).unwrap()
        });
        for r in &run.results {
            assert_eq!(r, &expected_sum(4, 2));
        }
    }

    #[test]
    fn plan_reuse_with_shifting_inputs() {
        let topo = Topology::regions(4, 4);
        let p = topo.size();
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let r = AllreduceRegistry::<u64>::standard();
            for name in r.names() {
                let mut plan = r.plan(name, c, Shape::elems(3)).unwrap();
                assert_eq!(plan.algorithm(), name);
                assert_eq!(plan.comm_size(), p);
                let mut out = vec![0u64; 3];
                for round in 0..5u64 {
                    let mine: Vec<u64> =
                        contribution(c.rank(), 3).iter().map(|v| v + round).collect();
                    plan.execute(&mine, &mut out).unwrap();
                    let expect: Vec<u64> = expected_sum(p, 3)
                        .iter()
                        .map(|v| v + round * p as u64)
                        .collect();
                    assert_eq!(out, expect, "{name} round {round}");
                }
            }
            true
        });
        assert!(run.results.iter().all(|&ok| ok));
    }
}
