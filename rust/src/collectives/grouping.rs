//! Grouping of communicator ranks by topology attributes.
//!
//! The locality-aware algorithms operate on *groups* of communicator ranks
//! (regions, nodes, sockets). Groups are computed from the globally-known
//! topology — no communication — and are therefore identical on every
//! member, mirroring what `MPI_Comm_split` would produce.

use crate::comm::Comm;
use crate::error::{Error, Result};

/// The attribute to group by.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GroupBy {
    /// The topology's configured region (node on Quartz, socket on Lassen).
    Region,
    /// Physical node (outer level of the multilevel algorithm).
    Node,
    /// Physical socket (inner level of the multilevel algorithm).
    Socket,
}

/// Result of grouping a communicator's ranks.
#[derive(Debug, Clone)]
pub struct Groups {
    /// Each group's member list, in communicator ranks, each sorted
    /// ascending; groups ordered by their smallest member.
    pub members: Vec<Vec<usize>>,
    /// Group index of the calling rank.
    pub mine: usize,
    /// The caller's position within its group.
    pub my_local: usize,
}

impl Groups {
    /// Group size if uniform across groups.
    pub fn uniform_size(&self) -> Option<usize> {
        let first = self.members.first()?.len();
        self.members
            .iter()
            .all(|g| g.len() == first)
            .then_some(first)
    }

    /// Number of groups.
    pub fn count(&self) -> usize {
        self.members.len()
    }
}

/// Group an arbitrary set of communicator ranks by a topology attribute.
///
/// `world_of` maps communicator rank → world rank; `ranks` is the subset
/// to group (ascending). Groups are ordered by smallest member, members
/// ascending — identical on every caller, like `MPI_Comm_split`. This is
/// the comm-free core used by schedule builders (which must be able to
/// derive any rank's groups, not just the caller's).
pub fn split_members(
    topo: &crate::topology::Topology,
    world_of: &[usize],
    ranks: &[usize],
    by: GroupBy,
) -> Vec<Vec<usize>> {
    let key = |world: usize| -> usize {
        match by {
            GroupBy::Region => topo.region_of(world),
            GroupBy::Node => topo.coord(world).node,
            GroupBy::Socket => {
                let c = topo.coord(world);
                c.node * topo.sockets_per_node() + c.socket
            }
        }
    };
    let mut buckets: std::collections::BTreeMap<usize, Vec<usize>> = Default::default();
    for &r in ranks {
        buckets.entry(key(world_of[r])).or_default().push(r);
    }
    let mut members: Vec<Vec<usize>> = buckets.into_values().collect();
    members.sort_by_key(|g| g[0]);
    members
}

/// Group the ranks of `comm` by the chosen attribute.
pub fn group_ranks(comm: &Comm, by: GroupBy) -> Result<Groups> {
    let world_of: Vec<usize> = (0..comm.size()).map(|r| comm.world_rank_of(r)).collect();
    let all: Vec<usize> = (0..comm.size()).collect();
    let members = split_members(comm.topology(), &world_of, &all, by);
    let me = comm.rank();
    let mine = members
        .iter()
        .position(|g| g.contains(&me))
        .ok_or_else(|| Error::Precondition("caller not in any group".into()))?;
    let my_local = members[mine]
        .iter()
        .position(|&r| r == me)
        .expect("member list contains caller");
    Ok(Groups { members, mine, my_local })
}

/// Require a uniform group size, erroring with a descriptive message.
pub fn require_uniform(groups: &Groups, algo: &str) -> Result<usize> {
    groups.uniform_size().ok_or_else(|| {
        Error::Precondition(format!(
            "{algo} requires equal-size groups; got sizes {:?}",
            groups.members.iter().map(|g| g.len()).collect::<Vec<_>>()
        ))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CommWorld, Timing};
    use crate::topology::{Placement, RegionKind, Topology};

    #[test]
    fn groups_by_region_block_placement() {
        let topo = Topology::regions(3, 2);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let g = group_ranks(c, GroupBy::Region).unwrap();
            (g.count(), g.mine, g.my_local, g.uniform_size())
        });
        assert_eq!(run.results[0], (3, 0, 0, Some(2)));
        assert_eq!(run.results[3], (3, 1, 1, Some(2)));
        assert_eq!(run.results[4], (3, 2, 0, Some(2)));
    }

    #[test]
    fn groups_by_socket_vs_node() {
        let topo =
            Topology::machine(2, 2, 2, RegionKind::Node, Placement::Block).unwrap();
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let n = group_ranks(c, GroupBy::Node).unwrap().count();
            let s = group_ranks(c, GroupBy::Socket).unwrap().count();
            (n, s)
        });
        assert!(run.results.iter().all(|&x| x == (2, 4)));
    }

    #[test]
    fn grouping_consistent_under_random_placement() {
        let topo = Topology::machine(
            2,
            1,
            4,
            RegionKind::Node,
            Placement::Random { seed: 3 },
        )
        .unwrap();
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            group_ranks(c, GroupBy::Region).unwrap().members
        });
        // every rank computes the identical group structure
        for r in &run.results {
            assert_eq!(r, &run.results[0]);
        }
        // and each group holds 4 ranks of one region
        let topo2 = topo.clone();
        for g in &run.results[0] {
            assert_eq!(g.len(), 4);
            let region = topo2.region_of(g[0]);
            assert!(g.iter().all(|&x| topo2.region_of(x) == region));
        }
    }
}
