//! All-to-all exchanges — the §6 extension, and the operation the original
//! Bruck et al. '97 paper [7] was designed for — as schedule builders.
//!
//! `alltoall` contract: rank `i` holds `p` blocks of `n` elements, block
//! `j` destined for rank `j`; afterwards rank `i` holds block `i` of every
//! rank, in rank order (`MPI_Alltoall` semantics).
//!
//! Three builders, all registered in [`super::plan::AlltoallRegistry`]
//! (plus the MPICH-style dispatcher in
//! [`super::dispatch::SystemDefaultAlltoall`] and the cost-model-driven
//! [`super::model_tuned::ModelTunedAlltoall`]):
//!
//! * **`pairwise`** — `p−1` rounds of `SendRecv` with XOR/shift partners:
//!   the large-message baseline (one message per peer, no forwarding);
//! * **`bruck`** — the classic log-step algorithm: `⌈log2(p)⌉` rounds where
//!   round `k` forwards every block whose destination distance has bit
//!   `k` set. Minimal message count, `O(b·log p)` forwarded bytes. The
//!   moving slot set of each round depends only on `(p, k)`, so the
//!   schedule precomputes it and the wire format needs no per-block
//!   headers;
//! * **`loc-aware`** — the paper's §6 direction applied to alltoall:
//!   aggregate per destination *region* locally (each local rank `ℓ`
//!   collects the blocks of all local peers headed for the region group it
//!   owns), exchange region-to-region (one aggregated non-local message
//!   per owned region), then scatter locally. Non-local messages per rank
//!   drop from `⌈log2 p⌉` (Bruck, mostly non-local) to `⌈(r−1)/pℓ⌉`
//!   aggregated transfers; non-local *duplicate* bytes disappear because
//!   payloads are aggregated once per region pair.
//!
//! All three are pure schedule builders executed by the generic
//! [`SchedPlan`] interpreter: schedules own their tag layouts and scratch,
//! `execute` is pure communication with zero allocation and no tag
//! consumption. Shape preconditions (uniform groups) surface at `plan()`
//! time; `n == 0` plans are uniform no-ops.

use super::grouping::GroupBy;
use super::plan::{
    trivial_a2a_plan, AlltoallAlgorithm, AlltoallPlan, NamedAlgorithm, OpKind, PlanSpec,
};
use super::schedule::{
    locate, uniform_size, SchedPlan, Schedule, ScheduleBuilder, Slice, WorldView,
};
use crate::comm::{Comm, Pod};
use crate::error::Result;

/// Pairwise-exchange alltoall (registry entry).
pub struct PairwiseAlltoall;

impl NamedAlgorithm for PairwiseAlltoall {
    fn name(&self) -> &'static str {
        "pairwise"
    }

    fn summary(&self) -> &'static str {
        "pairwise exchange: p-1 direct rounds, large-message baseline"
    }
}

impl<T: Pod> AlltoallAlgorithm<T> for PairwiseAlltoall {
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn AlltoallPlan<T>>> {
        if let Some(p) = trivial_a2a_plan("pairwise", comm, spec) {
            return Ok(p);
        }
        let n = spec.uniform_n("pairwise")?;
        let sched =
            build_pairwise_schedule(comm.size(), comm.rank(), n, std::mem::size_of::<T>());
        Ok(SchedPlan::<T>::boxed(comm, "pairwise", sched)?)
    }
}

/// Build the pairwise-exchange schedule for one rank (pure; SPMD). Round
/// `k` trades with `rank XOR k` (power-of-two `p`) or `(rank ± k) mod p`
/// otherwise; blocks move straight between the caller's buffers.
pub fn build_pairwise_schedule(
    p: usize,
    rank: usize,
    n: usize,
    elem_bytes: usize,
) -> Schedule {
    let mut sb = ScheduleBuilder::new("pairwise");
    sb.copy(Slice::input(rank * n, n), Slice::output(rank * n, n));
    for k in 1..p {
        let tag = sb.tag();
        let (dst, src) = if p.is_power_of_two() {
            (rank ^ k, rank ^ k)
        } else {
            ((rank + k) % p, (rank + p - k) % p)
        };
        sb.sendrecv(
            dst,
            Slice::input(dst * n, n),
            src,
            Slice::output(src * n, n),
            tag,
            0,
        );
    }
    sb.finish(OpKind::Alltoall, p, n, elem_bytes, "pairwise")
}

/// Bruck alltoall (registry entry).
pub struct BruckAlltoall;

impl NamedAlgorithm for BruckAlltoall {
    fn name(&self) -> &'static str {
        "bruck"
    }

    fn summary(&self) -> &'static str {
        "Bruck alltoall: log2(p) forwarding rounds, minimal message count"
    }
}

impl<T: Pod> AlltoallAlgorithm<T> for BruckAlltoall {
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn AlltoallPlan<T>>> {
        if let Some(p) = trivial_a2a_plan("bruck", comm, spec) {
            return Ok(p);
        }
        let n = spec.uniform_n("bruck")?;
        let sched = build_bruck_schedule(comm.size(), comm.rank(), n, std::mem::size_of::<T>());
        Ok(SchedPlan::<T>::boxed(comm, "bruck", sched)?)
    }
}

/// Build the Bruck alltoall schedule for one rank (pure; SPMD). Blocks are
/// kept in "distance" order (slot `d` holds the block currently destined
/// `d` ranks ahead); round `k` ships every slot with bit `k` set to rank
/// `id + 2^k`, headerless (the slot schedule is identical on both sides).
pub fn build_bruck_schedule(
    p: usize,
    rank: usize,
    n: usize,
    elem_bytes: usize,
) -> Schedule {
    let mut sb = ScheduleBuilder::new("rotate to distance order");
    let slots = sb.scratch(p * n);
    // slots[d] = input block (rank + d) mod p ⇔ dst slot (j + p - rank) % p
    // for input block j — a pure rotation.
    sb.rotate(
        Slice::input(0, p * n),
        Slice::at(slots, 0, p * n),
        n,
        (p - rank % p) % p,
    );
    let mut moving_max = 0usize;
    let mut k = 0u32;
    while (1usize << k) < p {
        moving_max = moving_max.max((0..p).filter(|d| d & (1usize << k) != 0).count());
        k += 1;
    }
    if moving_max > 0 {
        let pack = sb.scratch(moving_max * n);
        let unpack = sb.scratch(moving_max * n);
        let mut k = 0u32;
        while (1usize << k) < p {
            let bit = 1usize << k;
            sb.round(format!("round {k}"));
            let tag = sb.tag();
            let to = (rank + bit) % p;
            let from = (rank + p - bit) % p;
            let moving: Vec<usize> = (0..p).filter(|d| d & bit != 0).collect();
            for (i, &d) in moving.iter().enumerate() {
                sb.copy(Slice::at(slots, d * n, n), Slice::at(pack, i * n, n));
            }
            let len = moving.len() * n;
            sb.sendrecv(to, Slice::at(pack, 0, len), from, Slice::at(unpack, 0, len), tag, 0);
            // The receiver is `bit` closer to each destination: same slot
            // indices, same order — no headers needed.
            for (i, &d) in moving.iter().enumerate() {
                sb.copy(Slice::at(unpack, i * n, n), Slice::at(slots, d * n, n));
            }
            k += 1;
        }
    }
    // After all rounds slot d holds the block *from* rank (rank - d) mod p
    // destined for us. Unpack into rank order.
    sb.round("unrotate");
    for d in 0..p {
        let src = (rank + p - d) % p;
        sb.copy(Slice::at(slots, d * n, n), Slice::output(src * n, n));
    }
    sb.finish(OpKind::Alltoall, p, n, elem_bytes, "bruck")
}

/// Locality-aware alltoall (registry entry).
pub struct LocAwareAlltoall;

impl NamedAlgorithm for LocAwareAlltoall {
    fn name(&self) -> &'static str {
        "loc-aware"
    }

    fn summary(&self) -> &'static str {
        "region-aggregated alltoall (§6): one non-local message per owned region"
    }
}

impl<T: Pod> AlltoallAlgorithm<T> for LocAwareAlltoall {
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn AlltoallPlan<T>>> {
        if let Some(p) = trivial_a2a_plan("loc-aware", comm, spec) {
            return Ok(p);
        }
        let n = spec.uniform_n("loc-aware")?;
        let view = WorldView::from_comm(comm);
        let sched = build_loc_schedule(&view, comm.rank(), n, std::mem::size_of::<T>())?;
        Ok(SchedPlan::<T>::boxed(comm, "loc-aware", sched)?)
    }
}

/// Build the locality-aware alltoall schedule for one rank (pure; SPMD):
/// local gather per destination region → one aggregated non-local exchange
/// per (region, owner) pair → local scatter. Degrades to pairwise exchange
/// when there is no locality to exploit (one region, or one rank/region).
///
/// Local rank `ℓ` owns destination regions `{ℓ, ℓ+pℓ, ℓ+2pℓ, …}`; for each
/// owned region it receives the local peers' blocks (local gather),
/// exchanges one aggregated message with its counterpart in that region,
/// and finally the region scatters received aggregates locally. Non-local
/// messages per rank: `⌈(r−1)/pℓ⌉`, each `pℓ²·n` elements — no duplicate
/// values cross regions.
pub fn build_loc_schedule(
    view: &WorldView,
    rank: usize,
    n: usize,
    elem_bytes: usize,
) -> Result<Schedule> {
    let all: Vec<usize> = (0..view.p).collect();
    let groups = view.split(&all, GroupBy::Region);
    let ppr = uniform_size(&groups, "locality-aware alltoall")?;
    let r_n = groups.len();
    if ppr == 1 || r_n == 1 {
        let mut sched = build_pairwise_schedule(view.p, rank, n, elem_bytes);
        sched.label = "loc-aware[pairwise]".to_string();
        return Ok(sched);
    }
    let (g, l) = locate(&groups, rank)?;
    let r_n64 = r_n as u64;

    let mut sb = ScheduleBuilder::new("local direct exchange");
    // Tag layout: [0] local direct | [1, 1+r_n) gather by region |
    // [1+r_n, 1+r_n+r_n²) exchange by (from-region, to-region) |
    // [1+r_n+r_n², ...+r_n) scatter by region.
    let t0 = sb.tag_block(1 + r_n64 + r_n64 * r_n64 + r_n64);
    let tag_local = t0;
    let tag_gather = |rg: usize| t0 + 1 + rg as u64;
    let tag_xchg = |from_g: usize, to_g: usize| t0 + 1 + r_n64 + (from_g * r_n + to_g) as u64;
    let tag_scatter = |rg: usize| t0 + 1 + r_n64 + r_n64 * r_n64 + rg as u64;

    // Blocks for our own region move directly (one tag; distinct
    // (src, dst) pairs disambiguate).
    for &r in &groups[g] {
        if r == rank {
            sb.copy(Slice::input(rank * n, n), Slice::output(rank * n, n));
        } else {
            sb.send(r, Slice::input(r * n, n), tag_local, 0);
        }
    }
    for &r in &groups[g] {
        if r != rank {
            sb.recv(r, Slice::output(r * n, n), tag_local, 0);
        }
    }

    // Step 1: send my blocks for each remote region to its local owner.
    sb.round("aggregate per destination region");
    let sendagg = sb.scratch(ppr * n);
    for (rg, members) in groups.iter().enumerate() {
        if rg == g {
            continue;
        }
        let owner = groups[g][rg % ppr];
        for (i, &dst) in members.iter().enumerate() {
            sb.copy(Slice::input(dst * n, n), Slice::at(sendagg, i * n, n));
        }
        sb.send(owner, Slice::at(sendagg, 0, ppr * n), tag_gather(rg), 0);
    }

    // Steps 1b/2 for the regions I own: gather the region aggregate,
    // exchange it with rg's owner of OUR region.
    sb.round("aggregated exchange");
    let owned: Vec<usize> = (0..r_n).filter(|&rg| rg != g && rg % ppr == l).collect();
    let agg = sb.scratch(ppr * ppr * n);
    for &rg in &owned {
        for (j, &src) in groups[g].iter().enumerate() {
            sb.recv(src, Slice::at(agg, j * ppr * n, ppr * n), tag_gather(rg), 0);
        }
        let peer = groups[rg][g % ppr];
        sb.send(peer, Slice::at(agg, 0, ppr * ppr * n), tag_xchg(g, rg), 0);
    }

    // Step 3: receive the aggregates headed to our region from the regions
    // we own, and scatter rows to the local destinations.
    sb.round("scatter received aggregates");
    let got = sb.scratch(ppr * ppr * n);
    let per_dst = sb.scratch(ppr * n);
    for &rg in &owned {
        let peer = groups[rg][g % ppr];
        sb.recv(peer, Slice::at(got, 0, ppr * ppr * n), tag_xchg(rg, g), 0);
        // got layout: [src j in rg][dst k in g]; row k goes to member k.
        for (k, &dstr) in groups[g].iter().enumerate() {
            for j in 0..ppr {
                sb.copy(
                    Slice::at(got, j * ppr * n + k * n, n),
                    Slice::at(per_dst, j * n, n),
                );
            }
            if dstr == rank {
                for (j, &src) in groups[rg].iter().enumerate() {
                    sb.copy(Slice::at(per_dst, j * n, n), Slice::output(src * n, n));
                }
            } else {
                sb.send(dstr, Slice::at(per_dst, 0, ppr * n), tag_scatter(rg), 0);
            }
        }
    }
    // Receive scattered rows for regions owned by other local ranks.
    for (rg, members) in groups.iter().enumerate() {
        if rg == g || rg % ppr == l {
            continue;
        }
        let owner = groups[g][rg % ppr];
        sb.recv(owner, Slice::at(per_dst, 0, ppr * n), tag_scatter(rg), 0);
        for (j, &src) in members.iter().enumerate() {
            sb.copy(Slice::at(per_dst, j * n, n), Slice::output(src * n, n));
        }
    }
    Ok(sb.finish(OpKind::Alltoall, view.p, n, elem_bytes, "loc-aware"))
}

/// One-shot pairwise-exchange alltoall: plan + single execute.
/// `send.len()` must be a multiple of the communicator size.
pub fn pairwise<T: Pod>(comm: &Comm, send: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot_a2a(&PairwiseAlltoall, comm, send)
}

/// One-shot Bruck alltoall: plan + single execute.
pub fn bruck<T: Pod>(comm: &Comm, send: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot_a2a(&BruckAlltoall, comm, send)
}

/// One-shot locality-aware alltoall: plan + single execute.
pub fn loc_aware<T: Pod>(comm: &Comm, send: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot_a2a(&LocAwareAlltoall, comm, send)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::plan::{AlltoallRegistry, Shape};
    use crate::comm::{CommWorld, Timing};
    use crate::topology::Topology;

    /// send buffer for rank i: block j = [i*10_000 + j*100 + e]
    fn send_buf(i: usize, p: usize, n: usize) -> Vec<u64> {
        (0..p * n)
            .map(|x| {
                let (j, e) = (x / n, x % n);
                (i * 10_000 + j * 100 + e) as u64
            })
            .collect()
    }

    /// expected recv buffer for rank i
    fn want_buf(i: usize, p: usize, n: usize) -> Vec<u64> {
        (0..p * n)
            .map(|x| {
                let (j, e) = (x / n, x % n);
                (j * 10_000 + i * 100 + e) as u64
            })
            .collect()
    }

    fn check<F>(f: F, regions: usize, ppr: usize, n: usize)
    where
        F: Fn(&Comm, &[u64]) -> Result<Vec<u64>> + Sync,
    {
        let topo = Topology::regions(regions, ppr);
        let p = topo.size();
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            f(c, &send_buf(c.rank(), p, n)).unwrap()
        });
        for (rank, got) in run.results.iter().enumerate() {
            assert_eq!(got, &want_buf(rank, p, n), "rank {rank} ({regions}x{ppr})");
        }
    }

    #[test]
    fn pairwise_correct() {
        for (r, ppr, n) in [(1usize, 4usize, 2usize), (4, 4, 1), (3, 2, 3), (2, 8, 2)] {
            check(pairwise, r, ppr, n);
        }
    }

    #[test]
    fn bruck_correct() {
        for (r, ppr, n) in [(1usize, 4usize, 2usize), (4, 4, 1), (3, 2, 3), (2, 8, 2), (5, 2, 1)] {
            check(bruck, r, ppr, n);
        }
    }

    #[test]
    fn loc_aware_correct() {
        for (r, ppr, n) in [(4usize, 4usize, 2usize), (2, 4, 1), (8, 4, 1), (3, 4, 2), (6, 2, 2)] {
            check(loc_aware, r, ppr, n);
        }
    }

    #[test]
    fn loc_aware_fewer_nonlocal_messages_than_bruck() {
        let topo = Topology::regions(4, 4);
        let p = topo.size();
        let b = CommWorld::run(&topo, Timing::Wallclock, |c| {
            bruck(c, &send_buf(c.rank(), p, 1)).unwrap();
        });
        let l = CommWorld::run(&topo, Timing::Wallclock, |c| {
            loc_aware(c, &send_buf(c.rank(), p, 1)).unwrap();
        });
        assert!(
            l.trace.max_nonlocal_msgs() <= b.trace.max_nonlocal_msgs(),
            "loc {} vs bruck {}",
            l.trace.max_nonlocal_msgs(),
            b.trace.max_nonlocal_msgs()
        );
        // and strictly fewer total non-local bytes (no duplicate forwarding)
        assert!(l.trace.total_nonlocal_bytes() < b.trace.total_nonlocal_bytes());
    }

    #[test]
    fn bruck_equals_pairwise() {
        let topo = Topology::regions(2, 4);
        let p = topo.size();
        let a = CommWorld::run(&topo, Timing::Wallclock, |c| {
            bruck(c, &send_buf(c.rank(), p, 2)).unwrap()
        });
        let b = CommWorld::run(&topo, Timing::Wallclock, |c| {
            pairwise(c, &send_buf(c.rank(), p, 2)).unwrap()
        });
        assert_eq!(a.results, b.results);
    }

    #[test]
    fn ragged_buffer_rejected() {
        let topo = Topology::regions(1, 3);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            pairwise(c, &[1u64, 2]).is_err()
        });
        assert!(run.results.iter().all(|&b| b));
    }

    #[test]
    fn plan_reuse_with_shifting_inputs() {
        let topo = Topology::regions(4, 2);
        let p = topo.size();
        let n = 2usize;
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let r = AlltoallRegistry::<u64>::standard();
            for name in r.names() {
                let mut plan = r.plan_uniform(name, c, Shape::elems(n)).unwrap();
                assert_eq!(plan.algorithm(), name);
                assert_eq!(plan.comm_size(), p);
                let mut out = vec![0u64; n * p];
                for round in 0..5u64 {
                    let mine: Vec<u64> =
                        send_buf(c.rank(), p, n).iter().map(|v| v + round).collect();
                    plan.execute(&mine, &mut out).unwrap();
                    let expect: Vec<u64> =
                        want_buf(c.rank(), p, n).iter().map(|v| v + round).collect();
                    assert_eq!(out, expect, "{name} round {round}");
                }
            }
            true
        });
        assert!(run.results.iter().all(|&ok| ok));
    }
}
