//! All-to-all exchanges — the §6 extension, and the operation the original
//! Bruck et al. '97 paper [7] was designed for — as persistent plans.
//!
//! `alltoall` contract: rank `i` holds `p` blocks of `n` elements, block
//! `j` destined for rank `j`; afterwards rank `i` holds block `i` of every
//! rank, in rank order (`MPI_Alltoall` semantics).
//!
//! Three implementations, all [`AlltoallPlan`] factories registered in
//! [`super::plan::AlltoallRegistry`] (plus the MPICH-style dispatcher in
//! [`super::dispatch::SystemDefaultAlltoall`]):
//!
//! * **`pairwise`** — `p−1` rounds of `sendrecv` with XOR/shift partners:
//!   the large-message baseline (one message per peer, no forwarding);
//! * **`bruck`** — the classic log-step algorithm: `⌈log2(p)⌉` rounds where
//!   round `k` forwards every block whose destination distance has bit
//!   `k` set. Minimal message count, `O(b·log p)` forwarded bytes. The
//!   moving slot set of each round depends only on `(p, k)`, so the plan
//!   precomputes it and the wire format needs no per-block headers;
//! * **`loc-aware`** — the paper's §6 direction applied to alltoall:
//!   aggregate per destination *region* locally (each local rank `ℓ`
//!   collects the blocks of all local peers headed for the region group it
//!   owns), exchange region-to-region (one aggregated non-local message
//!   per owned region), then scatter locally. Non-local messages per rank
//!   drop from `⌈log2 p⌉` (Bruck, mostly non-local) to `⌈(r−1)/pℓ⌉`
//!   aggregated transfers; non-local *duplicate* bytes disappear because
//!   payloads are aggregated once per region pair.
//!
//! Plans own their schedules, tag blocks and scratch: `execute` is pure
//! communication with zero allocation and no tag consumption. Shape
//! preconditions (uniform groups) surface at `plan()` time; `n == 0`
//! plans are uniform no-ops.

use super::grouping::{group_ranks, require_uniform, GroupBy};
use super::plan::{
    check_a2a_io, trivial_a2a_plan, AlltoallAlgorithm, AlltoallPlan, CollectivePlan,
    NamedAlgorithm, PlanCore, SelectedPlan, Shape,
};
use crate::comm::{Comm, Pod};
use crate::error::Result;

/// Pairwise-exchange alltoall (registry entry).
pub struct PairwiseAlltoall;

impl NamedAlgorithm for PairwiseAlltoall {
    fn name(&self) -> &'static str {
        "pairwise"
    }

    fn summary(&self) -> &'static str {
        "pairwise exchange: p-1 direct rounds, large-message baseline"
    }
}

impl<T: Pod> AlltoallAlgorithm<T> for PairwiseAlltoall {
    fn plan(&self, comm: &Comm, shape: Shape) -> Result<Box<dyn AlltoallPlan<T>>> {
        if let Some(p) = trivial_a2a_plan("pairwise", comm, shape) {
            return Ok(p);
        }
        Ok(Box::new(PairwiseAlltoallPlan::<T>::new(comm, shape.n)))
    }
}

/// One pairwise round: whom to send to and receive from.
struct Pair {
    dst: usize,
    src: usize,
}

/// Persistent pairwise alltoall plan: partner schedule + tag block, zero
/// scratch (blocks move straight between the caller's buffers).
pub struct PairwiseAlltoallPlan<T: Pod> {
    core: PlanCore,
    rounds: Vec<Pair>,
    _elem: std::marker::PhantomData<T>,
}

impl<T: Pod> PairwiseAlltoallPlan<T> {
    /// Collectively plan a pairwise alltoall of `n`-element blocks.
    /// Round `k` trades with `rank XOR k` (power-of-two `p`) or
    /// `(rank ± k) mod p` otherwise.
    pub fn new(comm: &Comm, n: usize) -> PairwiseAlltoallPlan<T> {
        let p = comm.size();
        let id = comm.rank();
        let rounds: Vec<Pair> = (1..p)
            .map(|k| {
                if p.is_power_of_two() {
                    Pair { dst: id ^ k, src: id ^ k }
                } else {
                    Pair { dst: (id + k) % p, src: (id + p - k) % p }
                }
            })
            .collect();
        PairwiseAlltoallPlan {
            core: PlanCore::new(comm, n, rounds.len() as u64),
            rounds,
            _elem: std::marker::PhantomData,
        }
    }
}

impl<T: Pod> CollectivePlan for PairwiseAlltoallPlan<T> {
    fn algorithm(&self) -> &'static str {
        "pairwise"
    }

    fn shape(&self) -> Shape {
        Shape { n: self.core.n }
    }

    fn comm_size(&self) -> usize {
        self.core.p
    }
}

impl<T: Pod> AlltoallPlan<T> for PairwiseAlltoallPlan<T> {
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()> {
        let core = &self.core;
        check_a2a_io(core.n, core.p, input, output)?;
        if core.n == 0 {
            return Ok(());
        }
        let (n, id) = (core.n, core.id);
        output[id * n..(id + 1) * n].copy_from_slice(&input[id * n..(id + 1) * n]);
        for (k, pair) in self.rounds.iter().enumerate() {
            let tag = core.tag(k as u64);
            let _rq = core.comm.isend(&input[pair.dst * n..(pair.dst + 1) * n], pair.dst, tag)?;
            core.comm.recv_into(pair.src, tag, &mut output[pair.src * n..(pair.src + 1) * n])?;
        }
        Ok(())
    }
}

/// Bruck alltoall (registry entry).
pub struct BruckAlltoall;

impl NamedAlgorithm for BruckAlltoall {
    fn name(&self) -> &'static str {
        "bruck"
    }

    fn summary(&self) -> &'static str {
        "Bruck alltoall: log2(p) forwarding rounds, minimal message count"
    }
}

impl<T: Pod> AlltoallAlgorithm<T> for BruckAlltoall {
    fn plan(&self, comm: &Comm, shape: Shape) -> Result<Box<dyn AlltoallPlan<T>>> {
        if let Some(p) = trivial_a2a_plan("bruck", comm, shape) {
            return Ok(p);
        }
        Ok(Box::new(BruckAlltoallPlan::<T>::new(comm, shape.n)))
    }
}

/// One Bruck round: peers plus the (rank-independent) moving slot set.
struct A2aStep {
    to: usize,
    from: usize,
    /// Slot indices with round-bit set, ascending. The set depends only on
    /// `(p, k)`, so sender and receiver agree without headers.
    moving: Vec<usize>,
}

/// Persistent Bruck alltoall plan. Blocks are kept in "distance" order
/// (slot `d` holds the block currently destined `d` ranks ahead); round
/// `k` ships every slot with bit `k` set to rank `id + 2^k`, headerless
/// (the slot schedule is precomputed on both sides).
pub struct BruckAlltoallPlan<T: Pod> {
    core: PlanCore,
    steps: Vec<A2aStep>,
    /// slots[d·n..] = block destined for rank (id + d) mod p.
    slots: Vec<T>,
    /// Packed send payload scratch (largest round).
    pack: Vec<T>,
    /// Receive scratch (largest round).
    unpack: Vec<T>,
}

impl<T: Pod> BruckAlltoallPlan<T> {
    /// Collectively plan a Bruck alltoall of `n`-element blocks.
    pub fn new(comm: &Comm, n: usize) -> BruckAlltoallPlan<T> {
        let p = comm.size();
        let id = comm.rank();
        let mut steps = Vec::new();
        let mut k = 0u32;
        while (1usize << k) < p {
            let bit = 1usize << k;
            steps.push(A2aStep {
                to: (id + bit) % p,
                from: (id + p - bit) % p,
                moving: (0..p).filter(|d| d & bit != 0).collect(),
            });
            k += 1;
        }
        let max_moving = steps.iter().map(|s| s.moving.len()).max().unwrap_or(0);
        BruckAlltoallPlan {
            core: PlanCore::new(comm, n, steps.len() as u64),
            steps,
            slots: vec![T::default(); p * n],
            pack: vec![T::default(); max_moving * n],
            unpack: vec![T::default(); max_moving * n],
        }
    }
}

impl<T: Pod> CollectivePlan for BruckAlltoallPlan<T> {
    fn algorithm(&self) -> &'static str {
        "bruck"
    }

    fn shape(&self) -> Shape {
        Shape { n: self.core.n }
    }

    fn comm_size(&self) -> usize {
        self.core.p
    }
}

impl<T: Pod> AlltoallPlan<T> for BruckAlltoallPlan<T> {
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()> {
        let core = &self.core;
        check_a2a_io(core.n, core.p, input, output)?;
        if core.n == 0 {
            return Ok(());
        }
        let (n, p, id) = (core.n, core.p, core.id);
        // Rotate into distance order: slot d = block for rank (id + d).
        for d in 0..p {
            let dst = (id + d) % p;
            self.slots[d * n..(d + 1) * n].copy_from_slice(&input[dst * n..(dst + 1) * n]);
        }
        for (k, s) in self.steps.iter().enumerate() {
            let tag = core.tag(k as u64);
            let len = s.moving.len() * n;
            for (i, &d) in s.moving.iter().enumerate() {
                self.pack[i * n..(i + 1) * n].copy_from_slice(&self.slots[d * n..(d + 1) * n]);
            }
            let _rq = core.comm.isend(&self.pack[..len], s.to, tag)?;
            core.comm.recv_into(s.from, tag, &mut self.unpack[..len])?;
            // The receiver is `bit` closer to each destination: same slot
            // indices, same order — no headers needed.
            for (i, &d) in s.moving.iter().enumerate() {
                self.slots[d * n..(d + 1) * n].copy_from_slice(&self.unpack[i * n..(i + 1) * n]);
            }
        }
        // After all rounds slot d holds the block *from* rank (id - d)
        // mod p destined for us. Unpack into rank order.
        for d in 0..p {
            let src = (id + p - d) % p;
            output[src * n..(src + 1) * n].copy_from_slice(&self.slots[d * n..(d + 1) * n]);
        }
        Ok(())
    }
}

/// Locality-aware alltoall (registry entry).
pub struct LocAwareAlltoall;

impl NamedAlgorithm for LocAwareAlltoall {
    fn name(&self) -> &'static str {
        "loc-aware"
    }

    fn summary(&self) -> &'static str {
        "region-aggregated alltoall (§6): one non-local message per owned region"
    }
}

impl<T: Pod> AlltoallAlgorithm<T> for LocAwareAlltoall {
    fn plan(&self, comm: &Comm, shape: Shape) -> Result<Box<dyn AlltoallPlan<T>>> {
        if let Some(p) = trivial_a2a_plan("loc-aware", comm, shape) {
            return Ok(p);
        }
        LocAwareAlltoallPlan::<T>::plan_boxed(comm, shape.n)
    }
}

/// Persistent locality-aware alltoall plan: local gather per destination
/// region → one aggregated non-local exchange per (region, owner) pair →
/// local scatter.
///
/// Local rank `ℓ` owns destination regions `{ℓ, ℓ+pℓ, ℓ+2pℓ, …}`; for each
/// owned region it receives the local peers' blocks (local gather),
/// exchanges one aggregated message with its counterpart in that region,
/// and finally the region scatters received aggregates locally. Non-local
/// messages per rank: `⌈(r−1)/pℓ⌉`, each `pℓ²·n` elements — no duplicate
/// values cross regions.
pub struct LocAwareAlltoallPlan<T: Pod> {
    core: PlanCore,
    /// Group member lists in communicator ranks (regions by smallest rank).
    members: Vec<Vec<usize>>,
    g: usize,
    l: usize,
    ppr: usize,
    r_n: usize,
    /// Remote regions this rank owns (`rg != g && rg % ppr == l`).
    owned: Vec<usize>,
    /// Step-1 per-region aggregate of this rank's blocks, `ppr·n`.
    sendagg: Vec<T>,
    /// Gathered aggregate for one owned region, `ppr·ppr·n`
    /// (layout `[local src][dst in rg]`).
    agg: Vec<T>,
    /// Received aggregate from one owned region's peer, `ppr·ppr·n`.
    got: Vec<T>,
    /// One destination row of a received aggregate, `ppr·n`.
    per_dst: Vec<T>,
}

impl<T: Pod> LocAwareAlltoallPlan<T> {
    /// Collectively plan over `comm`, degrading to pairwise exchange when
    /// there is no locality to exploit (one region, or one rank/region).
    pub fn plan_boxed(comm: &Comm, n: usize) -> Result<Box<dyn AlltoallPlan<T>>> {
        let groups = group_ranks(comm, GroupBy::Region)?;
        let ppr = require_uniform(&groups, "locality-aware alltoall")?;
        let r_n = groups.count();
        if ppr == 1 || r_n == 1 {
            return Ok(Box::new(SelectedPlan {
                name: "loc-aware",
                inner: Box::new(PairwiseAlltoallPlan::<T>::new(comm, n))
                    as Box<dyn AlltoallPlan<T>>,
            }));
        }
        let g = groups.mine;
        let l = groups.my_local;
        let owned: Vec<usize> = (0..r_n).filter(|&rg| rg != g && rg % ppr == l).collect();
        // Tag layout: [0] local direct | [1, 1+r_n) gather by region |
        // [1+r_n, 1+r_n+r_n²) exchange by (from-region, to-region) |
        // [1+r_n+r_n², ...+r_n) scatter by region.
        let tags = 1 + r_n as u64 + (r_n * r_n) as u64 + r_n as u64;
        Ok(Box::new(LocAwareAlltoallPlan {
            core: PlanCore::new(comm, n, tags),
            members: groups.members,
            g,
            l,
            ppr,
            r_n,
            owned,
            sendagg: vec![T::default(); ppr * n],
            agg: vec![T::default(); ppr * ppr * n],
            got: vec![T::default(); ppr * ppr * n],
            per_dst: vec![T::default(); ppr * n],
        }))
    }
}

impl<T: Pod> CollectivePlan for LocAwareAlltoallPlan<T> {
    fn algorithm(&self) -> &'static str {
        "loc-aware"
    }

    fn shape(&self) -> Shape {
        Shape { n: self.core.n }
    }

    fn comm_size(&self) -> usize {
        self.core.p
    }
}

impl<T: Pod> AlltoallPlan<T> for LocAwareAlltoallPlan<T> {
    fn execute(&mut self, input: &[T], output: &mut [T]) -> Result<()> {
        check_a2a_io(self.core.n, self.core.p, input, output)?;
        let Self { core, members, g, l, ppr, r_n, owned, sendagg, agg, got, per_dst } = self;
        let (n, id, g, l, ppr, r_n) = (core.n, core.id, *g, *l, *ppr, *r_n);
        if n == 0 {
            return Ok(());
        }
        let comm = &core.comm;
        // Tag layout (see plan_boxed): local | gather | exchange | scatter.
        let tag_local = core.tag(0);
        let tag_gather = |rg: usize| core.tag(1 + rg as u64);
        let tag_xchg = |from_g: usize, to_g: usize| {
            core.tag(1 + r_n as u64 + (from_g * r_n + to_g) as u64)
        };
        let tag_scatter = |rg: usize| core.tag(1 + r_n as u64 + (r_n * r_n) as u64 + rg as u64);

        // Blocks for our own region move directly (one tag; distinct
        // (src, dst) pairs disambiguate).
        for &rank in members[g].iter() {
            if rank == id {
                output[id * n..(id + 1) * n].copy_from_slice(&input[id * n..(id + 1) * n]);
            } else {
                let _rq = comm.isend(&input[rank * n..(rank + 1) * n], rank, tag_local)?;
            }
        }
        for &rank in members[g].iter() {
            if rank != id {
                comm.recv_into(rank, tag_local, &mut output[rank * n..(rank + 1) * n])?;
            }
        }

        // Step 1: send my blocks for each remote region to its local owner.
        for rg in 0..r_n {
            if rg == g {
                continue;
            }
            let owner = members[g][rg % ppr];
            for (i, &dst) in members[rg].iter().enumerate() {
                sendagg[i * n..(i + 1) * n].copy_from_slice(&input[dst * n..(dst + 1) * n]);
            }
            let _rq = comm.isend(sendagg, owner, tag_gather(rg))?;
        }
        // Steps 1b/2 for the regions I own: gather the region aggregate,
        // exchange it with rg's owner of OUR region.
        for &rg in owned.iter() {
            for (j, &src) in members[g].iter().enumerate() {
                comm.recv_into(
                    src,
                    tag_gather(rg),
                    &mut agg[j * ppr * n..(j + 1) * ppr * n],
                )?;
            }
            let peer = members[rg][g % ppr];
            let _rq = comm.isend(agg, peer, tag_xchg(g, rg))?;
        }
        // Step 3: receive the aggregates headed to our region from the
        // regions we own, and scatter rows to the local destinations.
        for &rg in owned.iter() {
            let peer = members[rg][g % ppr];
            comm.recv_into(peer, tag_xchg(rg, g), &mut got[..])?;
            // got layout: [src j in rg][dst k in g]; row k goes to member k.
            for (k, &dst) in members[g].iter().enumerate() {
                for j in 0..ppr {
                    let base = j * ppr * n + k * n;
                    per_dst[j * n..(j + 1) * n].copy_from_slice(&got[base..base + n]);
                }
                if dst == id {
                    for (j, &src) in members[rg].iter().enumerate() {
                        output[src * n..(src + 1) * n]
                            .copy_from_slice(&per_dst[j * n..(j + 1) * n]);
                    }
                } else {
                    let _rq = comm.isend(per_dst, dst, tag_scatter(rg))?;
                }
            }
        }
        // Receive scattered rows for regions owned by other local ranks.
        for rg in 0..r_n {
            if rg == g || rg % ppr == l {
                continue;
            }
            let owner = members[g][rg % ppr];
            comm.recv_into(owner, tag_scatter(rg), &mut per_dst[..])?;
            for (j, &src) in members[rg].iter().enumerate() {
                output[src * n..(src + 1) * n].copy_from_slice(&per_dst[j * n..(j + 1) * n]);
            }
        }
        Ok(())
    }
}

/// One-shot pairwise-exchange alltoall: plan + single execute.
/// `send.len()` must be a multiple of the communicator size.
pub fn pairwise<T: Pod>(comm: &Comm, send: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot_a2a(&PairwiseAlltoall, comm, send)
}

/// One-shot Bruck alltoall: plan + single execute.
pub fn bruck<T: Pod>(comm: &Comm, send: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot_a2a(&BruckAlltoall, comm, send)
}

/// One-shot locality-aware alltoall: plan + single execute.
pub fn loc_aware<T: Pod>(comm: &Comm, send: &[T]) -> Result<Vec<T>> {
    super::plan::one_shot_a2a(&LocAwareAlltoall, comm, send)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::plan::AlltoallRegistry;
    use crate::comm::{CommWorld, Timing};
    use crate::topology::Topology;

    /// send buffer for rank i: block j = [i*10_000 + j*100 + e]
    fn send_buf(i: usize, p: usize, n: usize) -> Vec<u64> {
        (0..p * n)
            .map(|x| {
                let (j, e) = (x / n, x % n);
                (i * 10_000 + j * 100 + e) as u64
            })
            .collect()
    }

    /// expected recv buffer for rank i
    fn want_buf(i: usize, p: usize, n: usize) -> Vec<u64> {
        (0..p * n)
            .map(|x| {
                let (j, e) = (x / n, x % n);
                (j * 10_000 + i * 100 + e) as u64
            })
            .collect()
    }

    fn check<F>(f: F, regions: usize, ppr: usize, n: usize)
    where
        F: Fn(&Comm, &[u64]) -> Result<Vec<u64>> + Sync,
    {
        let topo = Topology::regions(regions, ppr);
        let p = topo.size();
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            f(c, &send_buf(c.rank(), p, n)).unwrap()
        });
        for (rank, got) in run.results.iter().enumerate() {
            assert_eq!(got, &want_buf(rank, p, n), "rank {rank} ({regions}x{ppr})");
        }
    }

    #[test]
    fn pairwise_correct() {
        for (r, ppr, n) in [(1usize, 4usize, 2usize), (4, 4, 1), (3, 2, 3), (2, 8, 2)] {
            check(pairwise, r, ppr, n);
        }
    }

    #[test]
    fn bruck_correct() {
        for (r, ppr, n) in [(1usize, 4usize, 2usize), (4, 4, 1), (3, 2, 3), (2, 8, 2), (5, 2, 1)] {
            check(bruck, r, ppr, n);
        }
    }

    #[test]
    fn loc_aware_correct() {
        for (r, ppr, n) in [(4usize, 4usize, 2usize), (2, 4, 1), (8, 4, 1), (3, 4, 2), (6, 2, 2)] {
            check(loc_aware, r, ppr, n);
        }
    }

    #[test]
    fn loc_aware_fewer_nonlocal_messages_than_bruck() {
        let topo = Topology::regions(4, 4);
        let p = topo.size();
        let b = CommWorld::run(&topo, Timing::Wallclock, |c| {
            bruck(c, &send_buf(c.rank(), p, 1)).unwrap();
        });
        let l = CommWorld::run(&topo, Timing::Wallclock, |c| {
            loc_aware(c, &send_buf(c.rank(), p, 1)).unwrap();
        });
        assert!(
            l.trace.max_nonlocal_msgs() <= b.trace.max_nonlocal_msgs(),
            "loc {} vs bruck {}",
            l.trace.max_nonlocal_msgs(),
            b.trace.max_nonlocal_msgs()
        );
        // and strictly fewer total non-local bytes (no duplicate forwarding)
        assert!(l.trace.total_nonlocal_bytes() < b.trace.total_nonlocal_bytes());
    }

    #[test]
    fn bruck_equals_pairwise() {
        let topo = Topology::regions(2, 4);
        let p = topo.size();
        let a = CommWorld::run(&topo, Timing::Wallclock, |c| {
            bruck(c, &send_buf(c.rank(), p, 2)).unwrap()
        });
        let b = CommWorld::run(&topo, Timing::Wallclock, |c| {
            pairwise(c, &send_buf(c.rank(), p, 2)).unwrap()
        });
        assert_eq!(a.results, b.results);
    }

    #[test]
    fn ragged_buffer_rejected() {
        let topo = Topology::regions(1, 3);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            pairwise(c, &[1u64, 2]).is_err()
        });
        assert!(run.results.iter().all(|&b| b));
    }

    #[test]
    fn plan_reuse_with_shifting_inputs() {
        let topo = Topology::regions(4, 2);
        let p = topo.size();
        let n = 2usize;
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            let r = AlltoallRegistry::<u64>::standard();
            for name in r.names() {
                let mut plan = r.plan(name, c, Shape::elems(n)).unwrap();
                assert_eq!(plan.algorithm(), name);
                assert_eq!(plan.comm_size(), p);
                let mut out = vec![0u64; n * p];
                for round in 0..5u64 {
                    let mine: Vec<u64> =
                        send_buf(c.rank(), p, n).iter().map(|v| v + round).collect();
                    plan.execute(&mine, &mut out).unwrap();
                    let expect: Vec<u64> =
                        want_buf(c.rank(), p, n).iter().map(|v| v + round).collect();
                    assert_eq!(out, expect, "{name} round {round}");
                }
            }
            true
        });
        assert!(run.results.iter().all(|&ok| ok));
    }
}
