//! All-to-all exchanges — the §6 extension, and the operation the original
//! Bruck et al. '97 paper [7] was designed for.
//!
//! `alltoall` contract: rank `i` holds `p` blocks of `n` elements, block
//! `j` destined for rank `j`; afterwards rank `i` holds block `i` of every
//! rank, in rank order (`MPI_Alltoall` semantics).
//!
//! Three implementations:
//!
//! * [`pairwise`] — `p−1` rounds of `sendrecv` with XOR/shift partners:
//!   the large-message baseline (one message per peer, no forwarding);
//! * [`bruck`] — the classic log-step algorithm: `⌈log2(p)⌉` rounds where
//!   round `k` forwards every block whose destination distance has bit
//!   `k` set. Minimal message count, `O(b·log p)` forwarded bytes;
//! * [`loc_aware`] — the paper's §6 direction applied to alltoall:
//!   aggregate per destination *region* locally (each local rank `ℓ`
//!   collects the blocks of all local peers headed for the region group it
//!   owns), exchange region-to-region in `r−1`-free fashion (one non-local
//!   message per owned region), then scatter locally. Non-local messages
//!   per rank drop from `⌈log2 p⌉` (Bruck, mostly non-local) to
//!   `⌈(r−1)/pℓ⌉`-ish aggregated transfers; non-local *duplicate* bytes
//!   disappear because payloads are aggregated once per region pair.

use super::grouping::{group_ranks, require_uniform, GroupBy};
use crate::comm::{Comm, Pod};
use crate::error::{Error, Result};

/// Check the send buffer length and return the block size `n`.
fn block_len<T>(comm: &Comm, send: &[T]) -> Result<usize> {
    let p = comm.size();
    if send.len() % p != 0 {
        return Err(Error::SizeMismatch { expected: (send.len() / p.max(1)) * p, got: send.len() });
    }
    Ok(send.len() / p)
}

/// Pairwise-exchange alltoall: `p − 1` rounds; round `k` trades with
/// `rank XOR k` (power-of-two p) or `(rank ± k) mod p` otherwise.
pub fn pairwise<T: Pod>(comm: &Comm, send: &[T]) -> Result<Vec<T>> {
    let p = comm.size();
    let id = comm.rank();
    let n = block_len(comm, send)?;
    let tag = comm.next_coll_tag();
    let mut out = vec![T::default(); n * p];
    out[id * n..(id + 1) * n].copy_from_slice(&send[id * n..(id + 1) * n]);
    for k in 1..p {
        let (dst, src) = if p.is_power_of_two() {
            (id ^ k, id ^ k)
        } else {
            ((id + k) % p, (id + p - k) % p)
        };
        let _rq = comm.isend(&send[dst * n..(dst + 1) * n], dst, tag + k as u64)?;
        comm.recv_into(src, tag + k as u64, &mut out[src * n..(src + 1) * n])?;
    }
    Ok(out)
}

/// Bruck alltoall: `⌈log2 p⌉` rounds. Blocks are kept in "distance" order
/// (slot `d` holds the block currently destined `d` ranks ahead); round
/// `k` ships every slot with bit `k` set to rank `id + 2^k`, prefixed by
/// the slot index so the receiver can merge.
pub fn bruck<T: Pod>(comm: &Comm, send: &[T]) -> Result<Vec<T>> {
    let p = comm.size();
    let id = comm.rank();
    let n = block_len(comm, send)?;
    if n == 0 {
        return Ok(Vec::new());
    }
    let tag = comm.next_coll_tag();

    // slots[d] = block destined for rank (id + d) mod p
    let mut slots: Vec<Vec<T>> = (0..p)
        .map(|d| {
            let dst = (id + d) % p;
            send[dst * n..(dst + 1) * n].to_vec()
        })
        .collect();

    let mut k = 0u32;
    while (1usize << k) < p {
        let bit = 1usize << k;
        let to = (id + bit) % p;
        let from = (id + p - bit) % p;
        // pack slot indices (u64) + payloads
        let moving: Vec<usize> = (0..p).filter(|d| d & bit != 0).collect();
        let mut payload: Vec<u8> = Vec::with_capacity(moving.len() * (8 + n * 8));
        for &d in &moving {
            payload.extend_from_slice(&(d as u64).to_le_bytes());
            payload.extend_from_slice(&crate::comm::to_bytes(&slots[d]));
        }
        let _rq = comm.isend(&payload, to, tag + k as u64)?;
        let got: Vec<u8> = comm.irecv(from, tag + k as u64).wait(comm)?;
        let rec = 8 + n * std::mem::size_of::<T>();
        if got.len() % rec != 0 {
            return Err(Error::DatatypeMismatch { bytes: got.len(), elem_size: rec });
        }
        for chunk in got.chunks_exact(rec) {
            let d = u64::from_le_bytes(chunk[0..8].try_into().expect("header")) as usize;
            if d >= p {
                return Err(Error::Precondition(format!("bruck alltoall: bad slot {d}")));
            }
            let body = crate::comm::from_bytes::<T>(&chunk[8..])
                .ok_or(Error::DatatypeMismatch { bytes: chunk.len() - 8, elem_size: std::mem::size_of::<T>() })?;
            // receiver is `bit` closer to the destination: same slot index
            slots[d] = body;
        }
        k += 1;
    }

    // slot d now holds the block that travelled to its destination… in
    // Bruck alltoall, after all rounds slot d holds the block *from* rank
    // (id - d) mod p destined for us. Unpack into rank order.
    let mut out = vec![T::default(); n * p];
    for d in 0..p {
        let src = (id + p - d) % p;
        out[src * n..(src + 1) * n].copy_from_slice(&slots[d]);
    }
    Ok(out)
}

/// Locality-aware alltoall (§6 direction): local gather per destination
/// region → one aggregated non-local exchange per (region, owner) pair →
/// local scatter.
///
/// Local rank `ℓ` owns destination regions `{ℓ, ℓ+pℓ, ℓ+2pℓ, …}`; for each
/// owned region it receives the local peers' blocks (local gather),
/// exchanges one aggregated message with its counterpart in that region,
/// and finally the region scatters received aggregates locally. Non-local
/// messages per rank: `⌈(r−1)/pℓ⌉`·1, each `pℓ²·n` elements — no duplicate
/// values cross regions.
pub fn loc_aware<T: Pod>(comm: &Comm, send: &[T]) -> Result<Vec<T>> {
    let p = comm.size();
    let id = comm.rank();
    let n = block_len(comm, send)?;
    if n == 0 {
        return Ok(Vec::new());
    }
    let groups = group_ranks(comm, GroupBy::Region)?;
    let ppr = require_uniform(&groups, "locality-aware alltoall")?;
    let r_n = groups.count();
    if ppr == 1 || r_n == 1 {
        return pairwise(comm, send);
    }
    let g = groups.mine;
    let l = groups.my_local;
    let local_comm = comm.sub(&groups.members[g])?;
    let tag = comm.next_coll_tag();

    let mut out = vec![T::default(); n * p];
    // Local blocks for our own region move directly.
    for (j, &rank) in groups.members[g].iter().enumerate() {
        let _ = j;
        if rank == id {
            out[id * n..(id + 1) * n].copy_from_slice(&send[id * n..(id + 1) * n]);
        } else {
            let ltag = tag; // one tag; distinct (src,dst) pairs
            let _rq = comm.isend(&send[rank * n..(rank + 1) * n], rank, ltag)?;
        }
    }
    for &rank in groups.members[g].iter() {
        if rank != id {
            comm.recv_into(rank, tag, &mut out[rank * n..(rank + 1) * n])?;
        }
    }

    // For every remote region rg (owned by local rank rg % ppr):
    //   1. local gather to the owner: each local rank sends its ppr blocks
    //      destined for rg's members;
    //   2. owner exchanges the aggregate with rg's owner of OUR region;
    //   3. owner scatters the received aggregate locally.
    let tag_gather = comm.next_coll_tag();
    let tag_xchg = comm.next_coll_tag();
    let tag_scatter = comm.next_coll_tag();
    // step 1: send my blocks for each remote region to its local owner
    for rg in 0..r_n {
        if rg == g {
            continue;
        }
        let owner = groups.members[g][rg % ppr];
        let mut blocks: Vec<T> = Vec::with_capacity(ppr * n);
        for &dst in &groups.members[rg] {
            blocks.extend_from_slice(&send[dst * n..(dst + 1) * n]);
        }
        let _rq = comm.isend(&blocks, owner, tag_gather + rg as u64)?;
    }
    // step 1b/2/3 for the regions I own
    let owned: Vec<usize> = (0..r_n).filter(|&rg| rg != g && rg % ppr == l).collect();
    let mut aggregates: Vec<(usize, Vec<T>)> = Vec::with_capacity(owned.len());
    for &rg in &owned {
        // gather ppr * ppr * n elements: [local src][dst in rg]
        let mut agg = vec![T::default(); ppr * ppr * n];
        for (j, &src) in groups.members[g].iter().enumerate() {
            comm.recv_into(
                src,
                tag_gather + rg as u64,
                &mut agg[j * ppr * n..(j + 1) * ppr * n],
            )?;
        }
        // exchange with rg's owner of region g
        let peer = groups.members[rg][g % ppr];
        let _rq = comm.isend(&agg, peer, tag_xchg + (g * r_n + rg) as u64)?;
        aggregates.push((rg, agg));
    }
    // receive the aggregates headed to our region from the regions we own
    for &rg in &owned {
        let peer = groups.members[rg][g % ppr];
        let got: Vec<T> = comm.irecv(peer, tag_xchg + (rg * r_n + g) as u64).wait(comm)?;
        if got.len() != ppr * ppr * n {
            return Err(Error::SizeMismatch { expected: ppr * ppr * n, got: got.len() });
        }
        // got layout: [src j in rg][dst k in g]; scatter row k to member k
        for (k, &dst) in groups.members[g].iter().enumerate() {
            let mut per_dst: Vec<T> = Vec::with_capacity(ppr * n);
            for j in 0..ppr {
                let base = j * ppr * n + k * n;
                per_dst.extend_from_slice(&got[base..base + n]);
            }
            if dst == id {
                for (j, &src) in groups.members[rg].iter().enumerate() {
                    out[src * n..(src + 1) * n]
                        .copy_from_slice(&per_dst[j * n..(j + 1) * n]);
                }
            } else {
                let _rq = comm.isend(&per_dst, dst, tag_scatter + rg as u64)?;
            }
        }
    }
    // receive scattered rows for regions owned by other local ranks
    for rg in 0..r_n {
        if rg == g || rg % ppr == l {
            continue;
        }
        let owner = groups.members[g][rg % ppr];
        let per_dst: Vec<T> = comm.irecv(owner, tag_scatter + rg as u64).wait(comm)?;
        if per_dst.len() != ppr * n {
            return Err(Error::SizeMismatch { expected: ppr * n, got: per_dst.len() });
        }
        for (j, &src) in groups.members[rg].iter().enumerate() {
            out[src * n..(src + 1) * n].copy_from_slice(&per_dst[j * n..(j + 1) * n]);
        }
    }
    let _ = local_comm;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CommWorld, Timing};
    use crate::topology::Topology;

    /// send buffer for rank i: block j = [i*10_000 + j*100 + e]
    fn send_buf(i: usize, p: usize, n: usize) -> Vec<u64> {
        (0..p * n)
            .map(|x| {
                let (j, e) = (x / n, x % n);
                (i * 10_000 + j * 100 + e) as u64
            })
            .collect()
    }

    /// expected recv buffer for rank i
    fn want_buf(i: usize, p: usize, n: usize) -> Vec<u64> {
        (0..p * n)
            .map(|x| {
                let (j, e) = (x / n, x % n);
                (j * 10_000 + i * 100 + e) as u64
            })
            .collect()
    }

    fn check<F>(f: F, regions: usize, ppr: usize, n: usize)
    where
        F: Fn(&Comm, &[u64]) -> Result<Vec<u64>> + Sync,
    {
        let topo = Topology::regions(regions, ppr);
        let p = topo.size();
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            f(c, &send_buf(c.rank(), p, n)).unwrap()
        });
        for (rank, got) in run.results.iter().enumerate() {
            assert_eq!(got, &want_buf(rank, p, n), "rank {rank} ({regions}x{ppr})");
        }
    }

    #[test]
    fn pairwise_correct() {
        for (r, ppr, n) in [(1usize, 4usize, 2usize), (4, 4, 1), (3, 2, 3), (2, 8, 2)] {
            check(pairwise, r, ppr, n);
        }
    }

    #[test]
    fn bruck_correct() {
        for (r, ppr, n) in [(1usize, 4usize, 2usize), (4, 4, 1), (3, 2, 3), (2, 8, 2), (5, 2, 1)] {
            check(bruck, r, ppr, n);
        }
    }

    #[test]
    fn loc_aware_correct() {
        for (r, ppr, n) in [(4usize, 4usize, 2usize), (2, 4, 1), (8, 4, 1), (3, 4, 2), (6, 2, 2)] {
            check(loc_aware, r, ppr, n);
        }
    }

    #[test]
    fn loc_aware_fewer_nonlocal_messages_than_bruck() {
        let topo = Topology::regions(4, 4);
        let p = topo.size();
        let b = CommWorld::run(&topo, Timing::Wallclock, |c| {
            bruck(c, &send_buf(c.rank(), p, 1)).unwrap();
        });
        let l = CommWorld::run(&topo, Timing::Wallclock, |c| {
            loc_aware(c, &send_buf(c.rank(), p, 1)).unwrap();
        });
        assert!(
            l.trace.max_nonlocal_msgs() <= b.trace.max_nonlocal_msgs(),
            "loc {} vs bruck {}",
            l.trace.max_nonlocal_msgs(),
            b.trace.max_nonlocal_msgs()
        );
        // and strictly fewer total non-local bytes (no duplicate forwarding)
        assert!(l.trace.total_nonlocal_bytes() < b.trace.total_nonlocal_bytes());
    }

    #[test]
    fn bruck_equals_pairwise() {
        let topo = Topology::regions(2, 4);
        let p = topo.size();
        let a = CommWorld::run(&topo, Timing::Wallclock, |c| {
            bruck(c, &send_buf(c.rank(), p, 2)).unwrap()
        });
        let b = CommWorld::run(&topo, Timing::Wallclock, |c| {
            pairwise(c, &send_buf(c.rank(), p, 2)).unwrap()
        });
        assert_eq!(a.results, b.results);
    }

    #[test]
    fn ragged_buffer_rejected() {
        let topo = Topology::regions(1, 3);
        let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
            pairwise(c, &[1u64, 2]).is_err()
        });
        assert!(run.results.iter().all(|&b| b));
    }
}
