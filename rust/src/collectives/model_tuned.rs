//! The model-tuned dispatcher: plan candidate schedules, score them with
//! the IR-derived cost model, select the cheapest.
//!
//! The adaptive counterpart to the MPICH-style static thresholds of
//! [`super::dispatch`]: where `system-default` mimics fixed byte cutoffs
//! (Thakur et al.), `model-tuned` builds the *actual* communication
//! schedule of every candidate algorithm for every rank, evaluates each
//! whole-world schedule set against the machine's locality-split postal
//! parameters ([`crate::model::cost::predict`], paper Eq. 2), and plans
//! the one with the lowest predicted completion time. Because prediction
//! replays exactly the clock algebra of the virtual transport, the
//! selection is provably the virtual-time-fastest candidate on the
//! modeled machine — the paper's "the winner flips with topology and
//! message size" argument turned into a dispatcher.
//!
//! Selection is deterministic and identical on every rank (schedules are
//! pure functions of topology + shape; candidates are scored in a fixed
//! order with strict comparison), so planning stays collective without
//! any communication. Under [`Timing::Wallclock`](crate::comm::Timing)
//! no machine parameters are attached to the communicator; the dispatcher
//! then scores against the Lassen preset (documented default).
//!
//! Planning cost: `O(candidates · p · steps)` per rank — fine for the
//! shapes the test-suite and figures use; plan once and reuse (the whole
//! point of the persistent API) when `p` grows large.

use std::collections::HashMap;
use std::sync::Mutex;

use super::plan::{
    check_counts_len, trivial_a2a_plan, trivial_agv_plan, trivial_plan, trivial_reduce_plan,
    trivial_rs_plan, trivial_rsv_plan, AllgatherPlan, AllgathervAlgorithm, AllgathervPlan,
    AllreduceAlgorithm, AllreducePlan, AlltoallAlgorithm, AlltoallPlan, CollectiveAlgorithm,
    NamedAlgorithm, PlanSpec, ReduceScatterAlgorithm, ReduceScatterPlan, ReduceScattervAlgorithm,
    ReduceScattervPlan, Summable,
};
use super::schedule::{
    build_allreduce, build_alltoall, build_reduce_scatter, SchedPlan, Schedule, WorldView,
};
use super::{Algorithm, OpKind};
use crate::comm::{Comm, Pod};
use crate::error::{Error, Result};
use crate::model::{cost, MachineParams};

/// Process-wide memo of dispatcher selections, keyed by the full decision
/// input (operation, shape, element size, topology+placement, machine).
/// Selection is a pure function of the key, and all ranks of a world plan
/// concurrently with identical keys — the winner is computed **while
/// holding the lock** ([`cached_winner`]), so concurrent ranks block on
/// the first scorer and reuse its result: `p` identical whole-world
/// scoring passes become one (plus `p` cheap winner rebuilds).
static SELECTION_CACHE: Mutex<Option<HashMap<String, String>>> = Mutex::new(None);

fn selection_key(
    op: OpKind,
    view: &WorldView,
    machine: &MachineParams,
    n: usize,
    elem_bytes: usize,
) -> String {
    format!(
        "{op:?}|{}|{n}|{elem_bytes}|{:?}|{machine:?}|{:?}",
        view.p, view.world_of, view.topo
    )
}

/// Entries kept before the memo is cleared: the cache is a perf
/// optimization for the SPMD planning burst (all ranks of one world share
/// one key), not a long-lived index — a sweep over many shapes must not
/// accumulate unbounded key strings.
const SELECTION_CACHE_CAP: usize = 32;

/// Look up the winner for `key`, computing (and memoizing) it with
/// `score` on a miss. The lock is held across `score` deliberately:
/// scoring is a pure function of the key, and the common contention is
/// the `p` ranks of one world planning the *same* key concurrently — they
/// should wait for the first result instead of repeating the whole-world
/// scoring pass. (Planners with a different key also wait; planning is
/// rare and bounded, and correctness never depends on the cache.)
fn cached_winner(key: String, score: impl FnOnce() -> Result<String>) -> Result<String> {
    let mut guard = SELECTION_CACHE.lock().unwrap_or_else(|e| e.into_inner());
    let map = guard.get_or_insert_with(HashMap::new);
    if let Some(w) = map.get(&key) {
        return Ok(w.clone());
    }
    let winner = score()?;
    if map.len() >= SELECTION_CACHE_CAP {
        map.clear();
    }
    map.insert(key, winner.clone());
    Ok(winner)
}

/// The candidate pool of the allgather dispatcher: every concrete
/// algorithm (dispatchers excluded), in scoring order (ties keep the
/// earlier entry). Slice constants, not arity-pinned arrays: growing a
/// pool must never require touching a length literal, and
/// `every_candidate_name_resolves_in_its_registry` pins each entry to a
/// registry name.
pub const ALLGATHER_CANDIDATES: &[Algorithm] = &[
    Algorithm::Bruck,
    Algorithm::Pat,
    Algorithm::Ring,
    Algorithm::RecursiveDoubling,
    Algorithm::Dissemination,
    Algorithm::Hierarchical,
    Algorithm::Multilane,
    Algorithm::LocalityBruck,
    Algorithm::LocalityBruckV,
    Algorithm::LocalityBruckMultilevel,
];

/// The candidate pool of the allreduce dispatcher. `rabenseifner` and
/// `loc-rabenseifner` admit every communicator size, so the pool as a
/// whole carries no power-of-two precondition.
pub const ALLREDUCE_CANDIDATES: &[&str] =
    &["recursive-doubling", "loc-aware", "rabenseifner", "loc-rabenseifner"];

/// The candidate pool of the alltoall dispatcher.
pub const ALLTOALL_CANDIDATES: &[&str] = &["pairwise", "bruck", "loc-aware"];

/// The candidate pool of the reduce-scatter dispatcher. `pat` is the
/// log-depth option at sizes recursive halving rejects.
pub const REDUCE_SCATTER_CANDIDATES: &[&str] = &["ring", "recursive-halving", "pat", "loc-aware"];

/// The candidate pool of the allgatherv dispatcher: every ragged builder
/// admits any counts vector, so no entry carries a shape precondition.
pub const ALLGATHERV_CANDIDATES: &[&str] = &["ring", "bruck", "loc-aware"];

/// The candidate pool of the reduce-scatter-v dispatcher.
pub const REDUCE_SCATTER_V_CANDIDATES: &[&str] = &["ring", "loc-aware"];

/// The machine the dispatcher scores against: the communicator's virtual
/// machine when present, otherwise the Lassen preset.
fn scoring_machine(comm: &Comm) -> MachineParams {
    comm.machine().cloned().unwrap_or_else(MachineParams::lassen)
}

/// Score candidate schedule sets and return the winner:
/// `(winning label, per-rank schedules)`. Candidates that fail to build
/// (shape preconditions) are skipped; if none builds, the last error is
/// returned.
fn pick<L: Clone, B>(
    labels: &[L],
    name_of: impl Fn(&L) -> String,
    build_all: B,
    view: &WorldView,
    machine: &MachineParams,
) -> Result<(String, Vec<Schedule>)>
where
    B: Fn(&L) -> Result<Vec<Schedule>>,
{
    let mut best: Option<(f64, String, Vec<Schedule>)> = None;
    let mut last_err: Option<Error> = None;
    for label in labels {
        match build_all(label) {
            Err(e) => last_err = Some(e),
            Ok(scheds) => {
                let t = cost::predict(&scheds, &view.topo, &view.world_of, machine)?;
                if best.as_ref().map_or(true, |(bt, _, _)| t < *bt) {
                    best = Some((t, name_of(label), scheds));
                }
            }
        }
    }
    match best {
        Some((_, name, scheds)) => Ok((name, scheds)),
        None => Err(last_err.unwrap_or_else(|| {
            Error::Precondition("model-tuned: no candidate algorithm admits this shape".into())
        })),
    }
}

/// Pick the cheapest allgather candidate for this world/shape: returns the
/// winning algorithm's name and all ranks' schedules (full scoring pass;
/// `locag explain` and tests use this — `plan()` goes through the cached
/// single-rank variant).
pub fn pick_allgather(
    view: &WorldView,
    machine: &MachineParams,
    n: usize,
    elem_bytes: usize,
) -> Result<(String, Vec<Schedule>)> {
    pick(
        ALLGATHER_CANDIDATES,
        |a| a.name().to_string(),
        |a| {
            (0..view.p)
                .map(|r| super::schedule::build_allgather(*a, view, r, n, elem_bytes))
                .collect()
        },
        view,
        machine,
    )
}

/// Pick the cheapest allreduce candidate (see [`pick_allgather`]).
pub fn pick_allreduce(
    view: &WorldView,
    machine: &MachineParams,
    n: usize,
    elem_bytes: usize,
) -> Result<(String, Vec<Schedule>)> {
    pick(
        ALLREDUCE_CANDIDATES,
        |s| s.to_string(),
        |s| (0..view.p).map(|r| build_allreduce(s, view, r, n, elem_bytes)).collect(),
        view,
        machine,
    )
}

/// Pick the cheapest reduce-scatter candidate (see [`pick_allgather`]).
pub fn pick_reduce_scatter(
    view: &WorldView,
    machine: &MachineParams,
    n: usize,
    elem_bytes: usize,
) -> Result<(String, Vec<Schedule>)> {
    pick(
        REDUCE_SCATTER_CANDIDATES,
        |s| s.to_string(),
        |s| (0..view.p).map(|r| build_reduce_scatter(s, view, r, n, elem_bytes)).collect(),
        view,
        machine,
    )
}

/// Pick the cheapest allgatherv candidate for these per-rank counts
/// (see [`pick_allgather`]).
pub fn pick_allgatherv(
    view: &WorldView,
    machine: &MachineParams,
    counts: &[usize],
    elem_bytes: usize,
) -> Result<(String, Vec<Schedule>)> {
    pick(
        ALLGATHERV_CANDIDATES,
        |s| s.to_string(),
        |s| {
            (0..view.p)
                .map(|r| super::allgatherv::build_allgatherv(s, view, r, counts, elem_bytes))
                .collect()
        },
        view,
        machine,
    )
}

/// Pick the cheapest reduce-scatter-v candidate for these per-rank counts
/// (see [`pick_allgather`]).
pub fn pick_reduce_scatter_v(
    view: &WorldView,
    machine: &MachineParams,
    counts: &[usize],
    elem_bytes: usize,
) -> Result<(String, Vec<Schedule>)> {
    pick(
        REDUCE_SCATTER_V_CANDIDATES,
        |s| s.to_string(),
        |s| {
            (0..view.p)
                .map(|r| {
                    super::reduce_scatter_v::build_reduce_scatter_v(s, view, r, counts, elem_bytes)
                })
                .collect()
        },
        view,
        machine,
    )
}

/// Pick the cheapest alltoall candidate (see [`pick_allgather`]).
pub fn pick_alltoall(
    view: &WorldView,
    machine: &MachineParams,
    n: usize,
    elem_bytes: usize,
) -> Result<(String, Vec<Schedule>)> {
    pick(
        ALLTOALL_CANDIDATES,
        |s| s.to_string(),
        |s| (0..view.p).map(|r| build_alltoall(s, view, r, n, elem_bytes)).collect(),
        view,
        machine,
    )
}

/// Cached selection + single-rank schedule build: what `plan()` uses so
/// that only the first rank of a world pays the whole-world scoring pass.
fn select_for_rank(
    op: OpKind,
    view: &WorldView,
    machine: &MachineParams,
    n: usize,
    elem_bytes: usize,
    rank: usize,
) -> Result<Schedule> {
    let key = selection_key(op, view, machine, n, elem_bytes);
    let winner = cached_winner(key, || {
        let (w, _) = match op {
            OpKind::Allgather => pick_allgather(view, machine, n, elem_bytes)?,
            OpKind::Allreduce => pick_allreduce(view, machine, n, elem_bytes)?,
            OpKind::Alltoall => pick_alltoall(view, machine, n, elem_bytes)?,
            OpKind::ReduceScatter => pick_reduce_scatter(view, machine, n, elem_bytes)?,
            OpKind::Allgatherv | OpKind::ReduceScatterV => {
                unreachable!("ragged ops dispatch through select_for_rank_v")
            }
        };
        Ok(w)
    })?;
    let mut sched = match op {
        OpKind::Allgather => super::schedule::build_allgather(
            Algorithm::parse(&winner).expect("cached winner is a candidate name"),
            view,
            rank,
            n,
            elem_bytes,
        )?,
        OpKind::Allreduce => build_allreduce(&winner, view, rank, n, elem_bytes)?,
        OpKind::Alltoall => build_alltoall(&winner, view, rank, n, elem_bytes)?,
        OpKind::ReduceScatter => build_reduce_scatter(&winner, view, rank, n, elem_bytes)?,
        OpKind::Allgatherv | OpKind::ReduceScatterV => {
            unreachable!("ragged ops dispatch through select_for_rank_v")
        }
    };
    sched.label = format!("model-tuned[{winner}]");
    Ok(sched)
}

/// Ragged counterpart of [`select_for_rank`]: the memo key carries the
/// full counts vector (selection legitimately flips with skew, not just
/// total size), and the winner's schedule is rebuilt for one rank from the
/// by-name ragged builders.
fn select_for_rank_v(
    op: OpKind,
    view: &WorldView,
    machine: &MachineParams,
    counts: &[usize],
    elem_bytes: usize,
    rank: usize,
) -> Result<Schedule> {
    let key = format!(
        "{op:?}|{}|{counts:?}|{elem_bytes}|{:?}|{machine:?}|{:?}",
        view.p, view.world_of, view.topo
    );
    let winner = cached_winner(key, || {
        let (w, _) = match op {
            OpKind::Allgatherv => pick_allgatherv(view, machine, counts, elem_bytes)?,
            OpKind::ReduceScatterV => pick_reduce_scatter_v(view, machine, counts, elem_bytes)?,
            _ => unreachable!("uniform ops dispatch through select_for_rank"),
        };
        Ok(w)
    })?;
    let mut sched = match op {
        OpKind::Allgatherv => {
            super::allgatherv::build_allgatherv(&winner, view, rank, counts, elem_bytes)?
        }
        OpKind::ReduceScatterV => super::reduce_scatter_v::build_reduce_scatter_v(
            &winner, view, rank, counts, elem_bytes,
        )?,
        _ => unreachable!("uniform ops dispatch through select_for_rank"),
    };
    sched.label = format!("model-tuned[{winner}]");
    Ok(sched)
}

/// The model-tuned allgather dispatcher (registry entry).
pub struct ModelTuned;

impl NamedAlgorithm for ModelTuned {
    fn name(&self) -> &'static str {
        "model-tuned"
    }

    fn summary(&self) -> &'static str {
        "cost-model dispatch: scores every candidate schedule, plans the cheapest"
    }
}

impl<T: Pod> CollectiveAlgorithm<T> for ModelTuned {
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn AllgatherPlan<T>>> {
        if let Some(p) = trivial_plan("model-tuned", comm, spec) {
            return Ok(p);
        }
        let n = spec.uniform_n("model-tuned")?;
        let view = WorldView::from_comm(comm);
        let machine = scoring_machine(comm);
        let sched = select_for_rank(
            OpKind::Allgather,
            &view,
            &machine,
            n,
            std::mem::size_of::<T>(),
            comm.rank(),
        )?;
        Ok(SchedPlan::<T>::boxed(comm, "model-tuned", sched)?)
    }
}

/// The model-tuned allreduce dispatcher (registry entry).
pub struct ModelTunedAllreduce;

impl NamedAlgorithm for ModelTunedAllreduce {
    fn name(&self) -> &'static str {
        "model-tuned"
    }

    fn summary(&self) -> &'static str {
        "cost-model dispatch over the allreduce candidates"
    }
}

impl<T: Summable> AllreduceAlgorithm<T> for ModelTunedAllreduce {
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn AllreducePlan<T>>> {
        if let Some(p) = trivial_reduce_plan("model-tuned", comm, spec) {
            return Ok(p);
        }
        let n = spec.uniform_n("model-tuned")?;
        let view = WorldView::from_comm(comm);
        let machine = scoring_machine(comm);
        let sched = select_for_rank(
            OpKind::Allreduce,
            &view,
            &machine,
            n,
            std::mem::size_of::<T>(),
            comm.rank(),
        )?;
        Ok(SchedPlan::<T>::boxed(comm, "model-tuned", sched)?)
    }
}

/// The model-tuned alltoall dispatcher (registry entry).
pub struct ModelTunedAlltoall;

impl NamedAlgorithm for ModelTunedAlltoall {
    fn name(&self) -> &'static str {
        "model-tuned"
    }

    fn summary(&self) -> &'static str {
        "cost-model dispatch over the alltoall candidates"
    }
}

impl<T: Pod> AlltoallAlgorithm<T> for ModelTunedAlltoall {
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn AlltoallPlan<T>>> {
        if let Some(p) = trivial_a2a_plan("model-tuned", comm, spec) {
            return Ok(p);
        }
        let n = spec.uniform_n("model-tuned")?;
        let view = WorldView::from_comm(comm);
        let machine = scoring_machine(comm);
        let sched = select_for_rank(
            OpKind::Alltoall,
            &view,
            &machine,
            n,
            std::mem::size_of::<T>(),
            comm.rank(),
        )?;
        Ok(SchedPlan::<T>::boxed(comm, "model-tuned", sched)?)
    }
}

/// The model-tuned reduce-scatter dispatcher (registry entry).
pub struct ModelTunedReduceScatter;

impl NamedAlgorithm for ModelTunedReduceScatter {
    fn name(&self) -> &'static str {
        "model-tuned"
    }

    fn summary(&self) -> &'static str {
        "cost-model dispatch over the reduce-scatter candidates"
    }
}

impl<T: Summable> ReduceScatterAlgorithm<T> for ModelTunedReduceScatter {
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn ReduceScatterPlan<T>>> {
        if let Some(p) = trivial_rs_plan("model-tuned", comm, spec) {
            return Ok(p);
        }
        let n = spec.uniform_n("model-tuned")?;
        let view = WorldView::from_comm(comm);
        let machine = scoring_machine(comm);
        let sched = select_for_rank(
            OpKind::ReduceScatter,
            &view,
            &machine,
            n,
            std::mem::size_of::<T>(),
            comm.rank(),
        )?;
        Ok(SchedPlan::<T>::boxed(comm, "model-tuned", sched)?)
    }
}

/// The model-tuned allgatherv dispatcher (registry entry).
pub struct ModelTunedAllgatherv;

impl NamedAlgorithm for ModelTunedAllgatherv {
    fn name(&self) -> &'static str {
        "model-tuned"
    }

    fn summary(&self) -> &'static str {
        "cost-model dispatch over the allgatherv candidates"
    }
}

impl<T: Pod> AllgathervAlgorithm<T> for ModelTunedAllgatherv {
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn AllgathervPlan<T>>> {
        if let Some(p) = trivial_agv_plan("model-tuned", comm, spec) {
            return Ok(p);
        }
        check_counts_len(&spec.counts, comm.size())?;
        let view = WorldView::from_comm(comm);
        let machine = scoring_machine(comm);
        let sched = select_for_rank_v(
            OpKind::Allgatherv,
            &view,
            &machine,
            spec.counts.as_slice(),
            std::mem::size_of::<T>(),
            comm.rank(),
        )?;
        Ok(SchedPlan::<T>::boxed(comm, "model-tuned", sched)?)
    }
}

/// The model-tuned reduce-scatter-v dispatcher (registry entry).
pub struct ModelTunedReduceScatterv;

impl NamedAlgorithm for ModelTunedReduceScatterv {
    fn name(&self) -> &'static str {
        "model-tuned"
    }

    fn summary(&self) -> &'static str {
        "cost-model dispatch over the reduce-scatter-v candidates"
    }
}

impl<T: Summable> ReduceScattervAlgorithm<T> for ModelTunedReduceScatterv {
    fn plan(&self, comm: &Comm, spec: &PlanSpec) -> Result<Box<dyn ReduceScattervPlan<T>>> {
        if let Some(p) = trivial_rsv_plan("model-tuned", comm, spec) {
            return Ok(p);
        }
        check_counts_len(&spec.counts, comm.size())?;
        let view = WorldView::from_comm(comm);
        let machine = scoring_machine(comm);
        let sched = select_for_rank_v(
            OpKind::ReduceScatterV,
            &view,
            &machine,
            spec.counts.as_slice(),
            std::mem::size_of::<T>(),
            comm.rank(),
        )?;
        Ok(SchedPlan::<T>::boxed(comm, "model-tuned", sched)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Topology;

    #[test]
    fn selection_is_deterministic_and_names_a_candidate() {
        let topo = Topology::regions(4, 4);
        let view = WorldView::world(&topo);
        let m = MachineParams::lassen();
        let (a, scheds) = pick_allgather(&view, &m, 2, 4).unwrap();
        let (b, _) = pick_allgather(&view, &m, 2, 4).unwrap();
        assert_eq!(a, b);
        assert_eq!(scheds.len(), 16);
        assert!(ALLGATHER_CANDIDATES.iter().any(|c| c.name() == a), "{a}");
    }

    #[test]
    fn picks_locality_aware_small_and_bandwidth_friendly_large() {
        // On a strongly locality-skewed machine the small-message winner
        // must exploit locality; at large sizes the winner must not be a
        // log-step duplicating algorithm.
        let topo = Topology::regions(8, 8);
        let view = WorldView::world(&topo);
        let m = MachineParams::lassen();
        let (small, _) = pick_allgather(&view, &m, 2, 4).unwrap();
        assert!(
            small.starts_with("loc-bruck") || small == "multilane" || small == "hierarchical",
            "small-message winner should be locality-aware, got {small}"
        );
        let (large, _) = pick_allgather(&view, &m, 1 << 15, 4).unwrap();
        assert_ne!(large, "bruck", "large messages must avoid duplicate forwarding");
        assert_ne!(large, "dissemination");
    }

    #[test]
    fn picks_the_predicted_fastest_candidate() {
        // Exhaustive cross-check on a small grid: the dispatcher's pick
        // must achieve the minimum predicted time over all candidates.
        let m = MachineParams::lassen();
        for (regions, ppr, n) in [(2usize, 2usize, 2usize), (4, 4, 2), (4, 2, 64), (2, 8, 2)] {
            let topo = Topology::regions(regions, ppr);
            let view = WorldView::world(&topo);
            let (winner, scheds) = pick_allgather(&view, &m, n, 4).unwrap();
            let t_win =
                crate::model::cost::predict(&scheds, &topo, &view.world_of, &m).unwrap();
            for &cand in ALLGATHER_CANDIDATES {
                let Ok(cs) = crate::model::cost::allgather_schedules(cand, &topo, n, 4) else {
                    continue;
                };
                let t = crate::model::cost::predict(&cs, &topo, &view.world_of, &m).unwrap();
                assert!(
                    t_win <= t + 1e-15,
                    "{regions}x{ppr} n={n}: picked {winner} ({t_win:.3e}) but {} is {t:.3e}",
                    cand.name()
                );
            }
        }
    }

    #[test]
    fn alltoall_and_allreduce_dispatchers_pick_valid_candidates() {
        let topo = Topology::regions(4, 4);
        let view = WorldView::world(&topo);
        let m = MachineParams::lassen();
        let (a2a, _) = pick_alltoall(&view, &m, 2, 8).unwrap();
        assert!(ALLTOALL_CANDIDATES.contains(&a2a.as_str()), "{a2a}");
        let (ar, _) = pick_allreduce(&view, &m, 2, 8).unwrap();
        assert!(ALLREDUCE_CANDIDATES.contains(&ar.as_str()), "{ar}");
        let (rs, scheds) = pick_reduce_scatter(&view, &m, 2, 8).unwrap();
        assert!(REDUCE_SCATTER_CANDIDATES.contains(&rs.as_str()), "{rs}");
        assert_eq!(scheds.len(), 16);
    }

    #[test]
    fn allreduce_dispatcher_admits_non_power_of_two_via_rabenseifner() {
        // p = 6: recursive doubling and the loc-aware fallback both reject,
        // but the Rabenseifner compositions admit any size — the
        // dispatcher no longer carries a power-of-two precondition.
        let topo = Topology::regions(3, 2);
        let view = WorldView::world(&topo);
        let (winner, scheds) =
            pick_allreduce(&view, &MachineParams::lassen(), 2, 8).unwrap();
        assert!(
            winner == "rabenseifner" || winner == "loc-rabenseifner",
            "expected a Rabenseifner composition, got {winner}"
        );
        assert_eq!(scheds.len(), 6);
    }

    #[test]
    fn every_candidate_name_resolves_in_its_registry() {
        use crate::collectives::plan::{
            AllgathervRegistry, AllreduceRegistry, AlltoallRegistry, ReduceScatterRegistry,
            ReduceScattervRegistry, Registry,
        };
        let reg = Registry::<u64>::standard();
        for &cand in ALLGATHER_CANDIDATES {
            assert!(reg.get(cand.name()).is_some(), "allgather candidate {cand} not registered");
        }
        let reg = AllreduceRegistry::<u64>::standard();
        for &cand in ALLREDUCE_CANDIDATES {
            assert!(reg.get(cand).is_some(), "allreduce candidate {cand} not registered");
        }
        let reg = AlltoallRegistry::<u64>::standard();
        for &cand in ALLTOALL_CANDIDATES {
            assert!(reg.get(cand).is_some(), "alltoall candidate {cand} not registered");
        }
        let reg = ReduceScatterRegistry::<u64>::standard();
        for &cand in REDUCE_SCATTER_CANDIDATES {
            assert!(reg.get(cand).is_some(), "reduce-scatter candidate {cand} not registered");
        }
        let reg = AllgathervRegistry::<u64>::standard();
        for &cand in ALLGATHERV_CANDIDATES {
            assert!(reg.get(cand).is_some(), "allgatherv candidate {cand} not registered");
        }
        let reg = ReduceScattervRegistry::<u64>::standard();
        for &cand in REDUCE_SCATTER_V_CANDIDATES {
            assert!(reg.get(cand).is_some(), "reduce-scatter-v candidate {cand} not registered");
        }
    }

    #[test]
    fn ragged_dispatchers_pick_valid_candidates_deterministically() {
        let topo = Topology::regions(4, 4);
        let view = WorldView::world(&topo);
        let m = MachineParams::lassen();
        let counts: Vec<usize> = (0..16).map(|r| r % 5).collect();
        let (agv, scheds) = pick_allgatherv(&view, &m, &counts, 8).unwrap();
        assert!(ALLGATHERV_CANDIDATES.contains(&agv.as_str()), "{agv}");
        assert_eq!(scheds.len(), 16);
        let (again, _) = pick_allgatherv(&view, &m, &counts, 8).unwrap();
        assert_eq!(agv, again);
        let (rsv, scheds) = pick_reduce_scatter_v(&view, &m, &counts, 8).unwrap();
        assert!(REDUCE_SCATTER_V_CANDIDATES.contains(&rsv.as_str()), "{rsv}");
        assert_eq!(scheds.len(), 16);
    }

    #[test]
    fn ragged_dispatchers_pick_the_predicted_fastest() {
        let m = MachineParams::lassen();
        for (regions, ppr) in [(2usize, 2usize), (4, 4), (2, 8)] {
            let topo = Topology::regions(regions, ppr);
            let view = WorldView::world(&topo);
            let p = regions * ppr;
            let counts: Vec<usize> = (0..p).map(|r| (r * 3) % 7).collect();
            let (winner, scheds) = pick_allgatherv(&view, &m, &counts, 8).unwrap();
            let t_win = crate::model::cost::predict(&scheds, &topo, &view.world_of, &m).unwrap();
            for &cand in ALLGATHERV_CANDIDATES {
                let cs: Vec<Schedule> = (0..p)
                    .map(|r| super::super::allgatherv::build_allgatherv(cand, &view, r, &counts, 8))
                    .collect::<Result<_>>()
                    .unwrap();
                let t = crate::model::cost::predict(&cs, &topo, &view.world_of, &m).unwrap();
                assert!(
                    t_win <= t + 1e-15,
                    "{regions}x{ppr}: picked {winner} ({t_win:.3e}) but {cand} is {t:.3e}"
                );
            }
        }
    }

    #[test]
    fn pat_wins_the_latency_bound_non_power_of_two_reduce_scatter() {
        // Flat non-power-of-two shapes at tiny n: recursive halving
        // rejects, loc-aware degenerates to the ring (ppr = 1), and the
        // ring pays p−1 latencies against PAT's ⌈log₂ p⌉ — the visible
        // model-tuned crossover the PAT builders exist for.
        let m = MachineParams::lassen();
        for (regions, ppr) in [(6usize, 1usize), (5, 1), (7, 1)] {
            let topo = Topology::regions(regions, ppr);
            let view = WorldView::world(&topo);
            let (winner, _) = pick_reduce_scatter(&view, &m, 1, 8).unwrap();
            assert_eq!(winner, "pat", "{regions}x{ppr}");
        }
        // ... while on a power-of-two locality shape PAT must lose: its
        // wrap-around ring-offset peers cross regions where recursive
        // halving's XOR peers (and loc-aware's lanes) stay local.
        let topo = Topology::regions(4, 4);
        let view = WorldView::world(&topo);
        for n in [2usize, 1 << 15] {
            let (winner, _) = pick_reduce_scatter(&view, &m, n, 8).unwrap();
            assert_ne!(winner, "pat", "4x4 n={n}");
        }
    }

    #[test]
    fn reduce_scatter_dispatcher_picks_the_predicted_fastest() {
        let m = MachineParams::lassen();
        for (regions, ppr, n) in [(2usize, 2usize, 2usize), (4, 4, 2), (4, 4, 512), (3, 2, 2)] {
            let topo = Topology::regions(regions, ppr);
            let view = WorldView::world(&topo);
            let (winner, scheds) = pick_reduce_scatter(&view, &m, n, 8).unwrap();
            let t_win =
                crate::model::cost::predict(&scheds, &topo, &view.world_of, &m).unwrap();
            for &cand in REDUCE_SCATTER_CANDIDATES {
                let built: Result<Vec<Schedule>> = (0..view.p)
                    .map(|r| build_reduce_scatter(cand, &view, r, n, 8))
                    .collect();
                let Ok(cs) = built else {
                    continue; // legitimate shape rejection (recursive halving)
                };
                let t = crate::model::cost::predict(&cs, &topo, &view.world_of, &m).unwrap();
                assert!(
                    t_win <= t + 1e-15,
                    "{regions}x{ppr} n={n}: picked {winner} ({t_win:.3e}) but {cand} is {t:.3e}"
                );
            }
        }
    }
}
