//! PJRT runtime: load and execute the AOT artifacts from the hot path.
//!
//! The build path (`make artifacts`) runs `python -m compile.aot` once,
//! producing HLO-text files plus `manifest.json`. At serving time this
//! module loads them through the `xla` crate:
//!
//! ```text
//! PjRtClient::cpu() → HloModuleProto::from_text_file → client.compile → execute
//! ```
//!
//! Python is never on the request path — after `make artifacts`, the Rust
//! binary is self-contained.

pub mod artifact;
pub mod client;

pub use artifact::{ArtifactSpec, Manifest, ModelDims};
pub use client::{Engine, Executable};
