//! PJRT client wrapper: compile HLO text once, execute many times.
//!
//! Two builds of this module exist:
//!
//! * `--features pjrt` — the real implementation over the `xla` crate
//!   (adapts the pattern of `/opt/xla-example/src/bin/load_hlo.rs`; all
//!   computations were lowered with `return_tuple=True`, so results are
//!   unwrapped with `to_tuple1()`). Requires the `xla` crate to be vendored
//!   into the build tree.
//! * default — a stub with the identical surface whose `Engine::load`
//!   reports that PJRT support is unavailable. The offline build
//!   environment has no crates.io access, so the default build must not
//!   reference `xla`; every consumer (coordinator, e2e CLI, integration
//!   tests) already degrades gracefully when the engine cannot load.
//!
//! Thread-safety (pjrt build): the PJRT CPU client is internally
//! synchronized, but the `xla` crate's handles are `!Sync`, so the
//! [`Engine`] is used behind a mutex by the coordinator's workers
//! (compilation happens once at startup; execution contention is measured
//! in the perf pass).

#[cfg(feature = "pjrt")]
mod imp {
    use std::collections::HashMap;
    use std::path::Path;

    use crate::error::{Error, Result};
    use crate::runtime::artifact::{ArtifactSpec, Manifest};

    /// One compiled computation.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        /// Expected input element counts (f32 inputs; the rotate artifact's
        /// scalar s32 input is handled explicitly).
        pub spec: ArtifactSpec,
    }

    impl Executable {
        /// Execute on f32 buffers shaped per the manifest entry.
        pub fn run_f32(&self, inputs: &[&[f32]]) -> Result<Vec<f32>> {
            if inputs.len() != self.spec.inputs.len() {
                return Err(Error::Runtime(format!(
                    "{}: expected {} inputs, got {}",
                    self.spec.name,
                    self.spec.inputs.len(),
                    inputs.len()
                )));
            }
            let mut lits = Vec::with_capacity(inputs.len());
            for (buf, ts) in inputs.iter().zip(&self.spec.inputs) {
                if buf.len() != ts.elems() {
                    return Err(Error::Runtime(format!(
                        "{}: input expected {} elems, got {}",
                        self.spec.name,
                        ts.elems(),
                        buf.len()
                    )));
                }
                let dims: Vec<i64> = ts.shape.iter().map(|&d| d as i64).collect();
                let lit = xla::Literal::vec1(buf)
                    .reshape(&dims)
                    .map_err(|e| Error::Runtime(format!("reshape: {e}")))?;
                lits.push(lit);
            }
            self.execute(lits)
        }

        /// Execute the rotate artifact: a flat f32 buffer plus an s32 scalar.
        pub fn run_rotate(&self, buf: &[f32], shift: i32) -> Result<Vec<f32>> {
            if self.spec.inputs.len() != 2 {
                return Err(Error::Runtime("rotate artifact expects 2 inputs".into()));
            }
            if buf.len() != self.spec.inputs[0].elems() {
                return Err(Error::Runtime(format!(
                    "rotate: buffer expected {} elems, got {}",
                    self.spec.inputs[0].elems(),
                    buf.len()
                )));
            }
            let b = xla::Literal::vec1(buf);
            let s = xla::Literal::from(shift);
            self.execute(vec![b, s])
        }

        fn execute(&self, lits: Vec<xla::Literal>) -> Result<Vec<f32>> {
            let result = self
                .exe
                .execute::<xla::Literal>(&lits)
                .map_err(|e| Error::Runtime(format!("{}: execute: {e}", self.spec.name)))?;
            let lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| Error::Runtime(format!("{}: to_literal: {e}", self.spec.name)))?;
            // lowered with return_tuple=True → 1-tuple
            let out = lit
                .to_tuple1()
                .map_err(|e| Error::Runtime(format!("{}: tuple unwrap: {e}", self.spec.name)))?;
            out.to_vec::<f32>()
                .map_err(|e| Error::Runtime(format!("{}: to_vec: {e}", self.spec.name)))
        }
    }

    /// The PJRT engine: one CPU client plus all compiled artifacts.
    pub struct Engine {
        #[allow(dead_code)]
        client: xla::PjRtClient,
        executables: HashMap<String, Executable>,
        pub manifest: Manifest,
    }

    impl Engine {
        /// Create a CPU client and compile every artifact in the manifest.
        pub fn load<P: AsRef<Path>>(artifact_dir: P) -> Result<Engine> {
            let manifest = Manifest::load(artifact_dir)?;
            let client = xla::PjRtClient::cpu()
                .map_err(|e| Error::Runtime(format!("PjRtClient::cpu: {e}")))?;
            let mut executables = HashMap::new();
            for spec in &manifest.artifacts {
                let proto = xla::HloModuleProto::from_text_file(
                    spec.path
                        .to_str()
                        .ok_or_else(|| Error::Runtime("non-UTF8 artifact path".into()))?,
                )
                .map_err(|e| Error::Runtime(format!("{}: parse HLO: {e}", spec.name)))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .map_err(|e| Error::Runtime(format!("{}: compile: {e}", spec.name)))?;
                executables.insert(spec.name.clone(), Executable { exe, spec: spec.clone() });
            }
            Ok(Engine { client, executables, manifest })
        }

        /// Look up a compiled artifact.
        pub fn executable(&self, name: &str) -> Result<&Executable> {
            self.executables
                .get(name)
                .ok_or_else(|| Error::Runtime(format!("no compiled artifact '{name}'")))
        }

        /// Names of all compiled artifacts.
        pub fn names(&self) -> Vec<&str> {
            let mut v: Vec<&str> = self.executables.keys().map(|s| s.as_str()).collect();
            v.sort_unstable();
            v
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use std::path::Path;

    use crate::error::{Error, Result};
    use crate::runtime::artifact::{ArtifactSpec, Manifest};

    fn no_pjrt() -> Error {
        Error::Runtime(
            "PJRT runtime unavailable: locag was built without the `pjrt` feature \
             (rebuild with `--features pjrt` and the vendored xla crate)"
            .into(),
        )
    }

    /// Stub of the compiled-computation handle (never constructed).
    pub struct Executable {
        /// Mirror of the real field so call sites type-check either way.
        pub spec: ArtifactSpec,
    }

    impl Executable {
        /// Always errors: PJRT support is not compiled in.
        pub fn run_f32(&self, _inputs: &[&[f32]]) -> Result<Vec<f32>> {
            Err(no_pjrt())
        }

        /// Always errors: PJRT support is not compiled in.
        pub fn run_rotate(&self, _buf: &[f32], _shift: i32) -> Result<Vec<f32>> {
            Err(no_pjrt())
        }
    }

    /// Stub engine. `load` validates the manifest (so missing-artifact
    /// diagnostics stay useful) and then reports the missing feature.
    pub struct Engine {
        pub manifest: Manifest,
    }

    impl Engine {
        /// Validate the manifest, then report that PJRT is unavailable.
        pub fn load<P: AsRef<Path>>(artifact_dir: P) -> Result<Engine> {
            let _manifest = Manifest::load(artifact_dir)?;
            Err(no_pjrt())
        }

        /// Always errors (an `Engine` can never be constructed).
        pub fn executable(&self, _name: &str) -> Result<&Executable> {
            Err(no_pjrt())
        }

        /// No compiled artifacts in the stub build.
        pub fn names(&self) -> Vec<&str> {
            Vec::new()
        }
    }
}

pub use imp::{Engine, Executable};

// Integration coverage for this module lives in
// `rust/tests/runtime_artifacts.rs` (needs `make artifacts` + the `pjrt`
// feature to have run; it skips loudly otherwise).
