//! Artifact manifest: what `python -m compile.aot` produced and how to
//! call it.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::Json;

/// Shape + dtype of one tensor as recorded in the manifest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    fn from_json(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Runtime("manifest entry missing shape".into()))?
            .iter()
            .map(|x| x.as_usize().ok_or_else(|| Error::Runtime("bad shape".into())))
            .collect::<Result<Vec<_>>>()?;
        let dtype = j
            .get("dtype")
            .and_then(Json::as_str)
            .unwrap_or("f32")
            .to_string();
        Ok(TensorSpec { shape, dtype })
    }

    /// Total element count.
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled computation.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    /// Absolute path of the HLO text file.
    pub path: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub output: TensorSpec,
}

/// Model dimensions baked into the artifacts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelDims {
    pub batch: usize,
    pub d_model: usize,
    pub d_hidden: usize,
    pub d_out: usize,
    pub tp: usize,
    pub params: usize,
}

impl ModelDims {
    /// Hidden width owned by each tensor-parallel worker.
    pub fn hidden_shard(&self) -> usize {
        self.d_hidden / self.tp
    }
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelDims,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {}/manifest.json (run `make artifacts` first): {e}",
                dir.display()
            ))
        })?;
        let j = Json::parse(&text).map_err(|e| Error::Runtime(format!("manifest: {e}")))?;
        let m = j
            .get("model")
            .ok_or_else(|| Error::Runtime("manifest missing 'model'".into()))?;
        let dim = |k: &str| -> Result<usize> {
            m.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| Error::Runtime(format!("manifest model missing '{k}'")))
        };
        let model = ModelDims {
            batch: dim("batch")?,
            d_model: dim("d_model")?,
            d_hidden: dim("d_hidden")?,
            d_out: dim("d_out")?,
            tp: dim("tp")?,
            params: dim("params")?,
        };
        let mut artifacts = Vec::new();
        let arts = j
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| Error::Runtime("manifest missing 'artifacts'".into()))?;
        for (name, a) in arts {
            let file = a
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| Error::Runtime(format!("artifact {name} missing file")))?;
            let path = dir.join(file);
            if !path.exists() {
                return Err(Error::Runtime(format!(
                    "artifact file {} missing",
                    path.display()
                )));
            }
            let inputs = a
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| Error::Runtime(format!("artifact {name} missing inputs")))?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let output = TensorSpec::from_json(
                a.get("output")
                    .ok_or_else(|| Error::Runtime(format!("artifact {name} missing output")))?,
            )?;
            artifacts.push(ArtifactSpec { name: name.clone(), path, inputs, output });
        }
        Ok(Manifest { dir, model, artifacts })
    }

    /// Find an artifact by name.
    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| Error::Runtime(format!("artifact '{name}' not in manifest")))
    }

    /// The default artifact directory: `$LOCAG_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("LOCAG_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, body: &str) {
        let mut f = std::fs::File::create(dir.join("manifest.json")).unwrap();
        f.write_all(body.as_bytes()).unwrap();
    }

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("locag_test_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    const GOOD: &str = r#"{
      "model": {"batch": 8, "d_model": 256, "d_hidden": 1024, "d_out": 256, "tp": 4, "params": 524288},
      "artifacts": {
        "partial_fwd": {"file": "partial_fwd.hlo.txt",
          "inputs": [{"shape": [8,256], "dtype": "f32"}, {"shape": [256,256], "dtype": "f32"}],
          "output": {"shape": [8,256], "dtype": "f32"}}
      }
    }"#;

    #[test]
    fn loads_valid_manifest() {
        let d = tmpdir("ok");
        write_manifest(&d, GOOD);
        std::fs::write(d.join("partial_fwd.hlo.txt"), "HloModule x").unwrap();
        let m = Manifest::load(&d).unwrap();
        assert_eq!(m.model.tp, 4);
        assert_eq!(m.model.hidden_shard(), 256);
        let a = m.artifact("partial_fwd").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.output.elems(), 8 * 256);
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn missing_file_is_reported() {
        let d = tmpdir("missing");
        write_manifest(&d, GOOD); // hlo file not written
        let err = Manifest::load(&d).unwrap_err();
        assert!(err.to_string().contains("missing"));
    }

    #[test]
    fn missing_manifest_mentions_make_artifacts() {
        let d = tmpdir("nomanifest");
        let err = Manifest::load(&d).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn malformed_json_is_reported() {
        let d = tmpdir("badjson");
        write_manifest(&d, "{not json");
        assert!(Manifest::load(&d).is_err());
    }
}
