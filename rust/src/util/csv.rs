//! Minimal CSV writer for the figure harness (no serde in the offline
//! environment; the schemas are simple and fixed).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use crate::error::Result;

/// Streaming CSV writer with a fixed header.
pub struct CsvWriter {
    out: Box<dyn Write>,
    cols: usize,
}

impl CsvWriter {
    /// Create a CSV file and write the header row.
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> Result<CsvWriter> {
        let f = File::create(path)?;
        let mut w = CsvWriter {
            out: Box::new(BufWriter::new(f)),
            cols: header.len(),
        };
        w.write_raw_row(header)?;
        Ok(w)
    }

    /// CSV to an arbitrary sink (used by tests and `--out -`).
    pub fn to_writer(out: Box<dyn Write>, header: &[&str]) -> Result<CsvWriter> {
        let mut w = CsvWriter {
            out,
            cols: header.len(),
        };
        w.write_raw_row(header)?;
        Ok(w)
    }

    fn write_raw_row(&mut self, fields: &[&str]) -> Result<()> {
        assert_eq!(
            fields.len(),
            self.cols,
            "CSV row has {} fields, header has {}",
            fields.len(),
            self.cols
        );
        let mut first = true;
        for f in fields {
            if !first {
                self.out.write_all(b",")?;
            }
            first = false;
            self.out.write_all(escape(f).as_bytes())?;
        }
        self.out.write_all(b"\n")?;
        Ok(())
    }

    /// Write one data row.
    pub fn row(&mut self, fields: &[String]) -> Result<()> {
        let refs: Vec<&str> = fields.iter().map(|s| s.as_str()).collect();
        self.write_raw_row(&refs)
    }

    /// Flush buffered output.
    pub fn flush(&mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

/// Quote a field if needed (commas, quotes, newlines).
fn escape(field: &str) -> String {
    if field.contains(',') || field.contains('"') || field.contains('\n') {
        format!("\"{}\"", field.replace('"', "\"\""))
    } else {
        field.to_string()
    }
}

/// Convenience macro to build a row of stringified fields.
#[macro_export]
macro_rules! csv_row {
    ($($x:expr),* $(,)?) => {
        vec![$(format!("{}", $x)),*]
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let buf: Vec<u8> = Vec::new();
        let cell = std::sync::Arc::new(std::sync::Mutex::new(buf));
        struct Sink(std::sync::Arc<std::sync::Mutex<Vec<u8>>>);
        impl Write for Sink {
            fn write(&mut self, b: &[u8]) -> std::io::Result<usize> {
                self.0.lock().unwrap().extend_from_slice(b);
                Ok(b.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let mut w =
            CsvWriter::to_writer(Box::new(Sink(cell.clone())), &["a", "b"]).unwrap();
        w.row(&csv_row![1, 2.5]).unwrap();
        w.row(&csv_row!["x,y", "q\"q"]).unwrap();
        w.flush().unwrap();
        let s = String::from_utf8(cell.lock().unwrap().clone()).unwrap();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[0], "a,b");
        assert_eq!(lines[1], "1,2.5");
        assert_eq!(lines[2], "\"x,y\",\"q\"\"q\"");
    }

    #[test]
    #[should_panic(expected = "fields")]
    fn wrong_arity_panics() {
        let sink = Box::new(std::io::sink());
        let mut w = CsvWriter::to_writer(sink, &["a", "b"]).unwrap();
        w.row(&csv_row![1]).unwrap();
    }
}
