//! Human-readable formatting helpers and a tiny ASCII line-plotter used by
//! the figure harness to preview series in the terminal.

/// Format a byte count: `512 B`, `8.0 KiB`, `2.5 MiB`.
pub fn bytes(n: usize) -> String {
    const KIB: f64 = 1024.0;
    let x = n as f64;
    if x < KIB {
        format!("{} B", n)
    } else if x < KIB * KIB {
        format!("{:.1} KiB", x / KIB)
    } else if x < KIB * KIB * KIB {
        format!("{:.1} MiB", x / (KIB * KIB))
    } else {
        format!("{:.2} GiB", x / (KIB * KIB * KIB))
    }
}

/// Format a duration in seconds: `1.23 us`, `45.6 ms`, `2.34 s`.
pub fn seconds(t: f64) -> String {
    if t < 1e-6 {
        format!("{:.1} ns", t * 1e9)
    } else if t < 1e-3 {
        format!("{:.2} us", t * 1e6)
    } else if t < 1.0 {
        format!("{:.2} ms", t * 1e3)
    } else {
        format!("{:.2} s", t)
    }
}

/// One labelled series for [`ascii_plot`].
pub struct Series<'a> {
    pub label: &'a str,
    /// (x, y) points; x and y must be positive for log-log plotting.
    pub points: &'a [(f64, f64)],
}

/// Render series as a log-log ASCII scatter chart (the paper's figures are
/// all log-log). Width/height are the inner plot dimensions.
pub fn ascii_plot(title: &str, series: &[Series<'_>], width: usize, height: usize) -> String {
    const MARKS: &[char] = &['*', 'o', '+', 'x', '#', '@', '%', '&'];
    let all: Vec<(f64, f64)> = series
        .iter()
        .flat_map(|s| s.points.iter().copied())
        .filter(|&(x, y)| x > 0.0 && y > 0.0)
        .collect();
    if all.is_empty() {
        return format!("{title}\n(no data)\n");
    }
    let (mut x0, mut x1, mut y0, mut y1) = (f64::MAX, f64::MIN, f64::MAX, f64::MIN);
    for &(x, y) in &all {
        x0 = x0.min(x.ln());
        x1 = x1.max(x.ln());
        y0 = y0.min(y.ln());
        y1 = y1.max(y.ln());
    }
    if (x1 - x0).abs() < 1e-12 {
        x1 = x0 + 1.0;
    }
    if (y1 - y0).abs() < 1e-12 {
        y1 = y0 + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let mark = MARKS[si % MARKS.len()];
        for &(x, y) in s.points {
            if x <= 0.0 || y <= 0.0 {
                continue;
            }
            let cx = (((x.ln() - x0) / (x1 - x0)) * (width - 1) as f64).round() as usize;
            let cy = (((y.ln() - y0) / (y1 - y0)) * (height - 1) as f64).round() as usize;
            grid[height - 1 - cy][cx] = mark;
        }
    }
    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    out.push_str(&format!("y: {} .. {} (log scale)\n", seconds(y0.exp()), seconds(y1.exp())));
    for row in &grid {
        out.push('|');
        out.extend(row.iter());
        out.push('\n');
    }
    out.push('+');
    out.extend(std::iter::repeat('-').take(width));
    out.push('\n');
    out.push_str(&format!(" x: {:.3e} .. {:.3e} (log scale)\n", x0.exp(), x1.exp()));
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!("  {} {}\n", MARKS[si % MARKS.len()], s.label));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_units() {
        assert_eq!(bytes(12), "12 B");
        assert_eq!(bytes(2048), "2.0 KiB");
        assert_eq!(bytes(3 * 1024 * 1024), "3.0 MiB");
    }

    #[test]
    fn seconds_units() {
        assert_eq!(seconds(5e-10), "0.5 ns");
        assert_eq!(seconds(2.5e-6), "2.50 us");
        assert_eq!(seconds(0.012), "12.00 ms");
        assert_eq!(seconds(3.0), "3.00 s");
    }

    #[test]
    fn plot_contains_marks_and_labels() {
        let pts = [(1.0, 1.0), (10.0, 10.0), (100.0, 100.0)];
        let s = ascii_plot(
            "demo",
            &[Series { label: "diag", points: &pts }],
            40,
            10,
        );
        assert!(s.contains("demo"));
        assert!(s.contains('*'));
        assert!(s.contains("diag"));
    }

    #[test]
    fn plot_handles_empty() {
        let s = ascii_plot("empty", &[], 10, 5);
        assert!(s.contains("no data"));
    }
}
