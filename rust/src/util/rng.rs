//! Deterministic pseudo-random number generation.
//!
//! The offline build environment has no `rand` crate, so we carry a small,
//! well-known generator: **xoshiro256\*\*** seeded through SplitMix64. It is
//! used by placement shuffling, workload generation and the in-tree property
//! testing kit — all places where reproducibility from a printed seed
//! matters more than cryptographic quality.

/// xoshiro256** — public-domain generator by Blackman & Vigna.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    /// Next raw 64 bits.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform integer in `[0, bound)`. `bound` must be non-zero.
    pub fn gen_range(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "gen_range(0)");
        // Lemire-style rejection-free-enough reduction; bias is negligible
        // for the bounds used here (all << 2^32).
        (self.next_u64() % bound as u64) as usize
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn gen_range_inclusive(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.gen_range(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// True with probability `p`.
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn range_bounds_respected() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.gen_range(13);
            assert!(v < 13);
            let w = r.gen_range_inclusive(5, 9);
            assert!((5..=9).contains(&w));
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn shuffle_moves_things() {
        let mut r = Rng::new(11);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }
}
