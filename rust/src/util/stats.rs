//! Summary statistics for measurement series (wall-clock benches, latency
//! distributions in the coordinator).

/// Simple summary of a sample set.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub p10: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub stddev: f64,
}

impl Summary {
    /// Compute a summary; returns `None` for an empty sample set.
    pub fn of(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            return None;
        }
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Some(Summary {
            n,
            mean,
            min: sorted[0],
            max: sorted[n - 1],
            p10: percentile_sorted(&sorted, 0.10),
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p99: percentile_sorted(&sorted, 0.99),
            stddev: var.sqrt(),
        })
    }
}

/// Nearest-rank percentile on a pre-sorted slice; `q` in `[0, 1]`.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    let idx = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Mean of a slice (0.0 for an empty slice).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Median via sort-copy.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN"));
    percentile_sorted(&v, 0.5)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = Summary::of(&[2.0; 10]).unwrap();
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 2.0);
        assert_eq!(s.p50, 2.0);
        assert_eq!(s.stddev, 0.0);
    }

    #[test]
    fn summary_of_empty_is_none() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn percentiles_ordered() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let s = Summary::of(&xs).unwrap();
        assert!(s.p10 <= s.p50 && s.p50 <= s.p90 && s.p90 <= s.p99);
        assert_eq!(s.min, 0.0);
        assert_eq!(s.max, 99.0);
        assert!((s.p50 - 49.5).abs() <= 1.0);
    }

    #[test]
    fn median_small() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[]), 0.0);
    }
}
