//! Minimal JSON parser (offline environment has no serde).
//!
//! Supports the full JSON grammar minus exotic escapes (`\uXXXX` is decoded
//! for the BMP only), which is more than the artifact manifest needs. Used
//! by [`crate::runtime::artifact`] to read `artifacts/manifest.json`.

use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array element access.
    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    /// As string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// As usize (must be a non-negative integral number).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// As object map.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let e = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u escape")?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(format!("bad escape '\\{}'", e as char)),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    let len = utf8_len(self.b[start]);
                    if start + len > self.b.len() {
                        return Err("truncated UTF-8".into());
                    }
                    let s = std::str::from_utf8(&self.b[start..start + len])
                        .map_err(|_| "invalid UTF-8")?;
                    out.push_str(s);
                    self.i += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-'
        ) {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shaped_document() {
        let doc = r#"{
            "model": {"tp": 4, "batch": 8, "params": 524288},
            "artifacts": {
                "partial_fwd": {"file": "partial_fwd.hlo.txt",
                                 "inputs": [{"shape": [8, 256], "dtype": "f32"}],
                                 "output": {"shape": [8, 256], "dtype": "f32"}}
            }
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("model").unwrap().get("tp").unwrap().as_usize(), Some(4));
        let pf = j.get("artifacts").unwrap().get("partial_fwd").unwrap();
        assert_eq!(pf.get("file").unwrap().as_str(), Some("partial_fwd.hlo.txt"));
        let shape = pf.get("inputs").unwrap().idx(0).unwrap().get("shape").unwrap();
        assert_eq!(shape.idx(0).unwrap().as_usize(), Some(8));
    }

    #[test]
    fn parses_scalars_and_arrays() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(
            Json::parse(r#"[1, "a", null]"#).unwrap(),
            Json::Arr(vec![Json::Num(1.0), Json::Str("a".into()), Json::Null])
        );
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""a\n\t\"\\ A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\n\t\"\\ A"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn nested_empty_containers() {
        let j = Json::parse(r#"{"a": [], "b": {}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 0);
        assert_eq!(j.get("b").unwrap().as_obj().unwrap().len(), 0);
    }
}
