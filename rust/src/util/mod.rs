//! Small shared utilities: deterministic RNG, statistics, CSV output and
//! human-readable formatting.

pub mod csv;
pub mod fmt;
pub mod json;
pub mod rng;
pub mod stats;

/// Integer log base 2, rounded down. `ilog2_floor(1) == 0`.
///
/// # Panics
/// Panics if `x == 0`.
pub fn ilog2_floor(x: usize) -> u32 {
    assert!(x > 0, "ilog2_floor(0)");
    usize::BITS - 1 - x.leading_zeros()
}

/// Integer log base 2, rounded up. `ilog2_ceil(1) == 0`.
///
/// # Panics
/// Panics if `x == 0`.
pub fn ilog2_ceil(x: usize) -> u32 {
    let f = ilog2_floor(x);
    if x.is_power_of_two() {
        f
    } else {
        f + 1
    }
}

/// Integer log base `b`, rounded up: the smallest `k` with `b^k >= x`.
///
/// This is the number of non-local steps of the locality-aware Bruck
/// algorithm for `x` regions with `b` processes per region.
///
/// # Panics
/// Panics if `b < 2` or `x == 0`.
pub fn ilog_ceil(b: usize, x: usize) -> u32 {
    assert!(b >= 2, "ilog_ceil base must be >= 2");
    assert!(x > 0, "ilog_ceil(.., 0)");
    let mut k = 0u32;
    let mut pow = 1usize;
    while pow < x {
        pow = pow.saturating_mul(b);
        k += 1;
    }
    k
}

/// `b^e` with saturation (used for step distances in loc-bruck).
pub fn ipow(b: usize, e: u32) -> usize {
    let mut out = 1usize;
    for _ in 0..e {
        out = out.saturating_mul(b);
    }
    out
}

/// True if `x` is a whole power of `b` (`b >= 2`). `is_power_of(1, b)` is true.
pub fn is_power_of(x: usize, b: usize) -> bool {
    assert!(b >= 2);
    if x == 0 {
        return false;
    }
    let mut v = x;
    while v % b == 0 {
        v /= b;
    }
    v == 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log2_floor_and_ceil() {
        assert_eq!(ilog2_floor(1), 0);
        assert_eq!(ilog2_floor(2), 1);
        assert_eq!(ilog2_floor(3), 1);
        assert_eq!(ilog2_floor(4), 2);
        assert_eq!(ilog2_ceil(1), 0);
        assert_eq!(ilog2_ceil(2), 1);
        assert_eq!(ilog2_ceil(3), 2);
        assert_eq!(ilog2_ceil(1024), 10);
        assert_eq!(ilog2_ceil(1025), 11);
    }

    #[test]
    fn logb_ceil() {
        // 4 regions, 4 ppn -> one non-local step (paper Example 2.1).
        assert_eq!(ilog_ceil(4, 4), 1);
        // 16 regions, 4 ppn -> two steps (paper Fig. 6).
        assert_eq!(ilog_ceil(4, 16), 2);
        assert_eq!(ilog_ceil(4, 17), 3);
        assert_eq!(ilog_ceil(2, 1), 0);
        assert_eq!(ilog_ceil(16, 1024), 3);
    }

    #[test]
    fn ipow_saturates() {
        assert_eq!(ipow(4, 0), 1);
        assert_eq!(ipow(4, 3), 64);
        assert_eq!(ipow(usize::MAX, 2), usize::MAX);
    }

    #[test]
    fn power_of() {
        assert!(is_power_of(1, 4));
        assert!(is_power_of(16, 4));
        assert!(!is_power_of(8, 4));
        assert!(!is_power_of(0, 4));
        assert!(is_power_of(27, 3));
    }
}
