//! Serving metrics: latency distribution, phase breakdown, throughput.

use crate::util::stats::Summary;

/// Per-request phase timings (seconds).
#[derive(Debug, Clone, Copy, Default)]
pub struct RequestTiming {
    /// PJRT partial forward (compute).
    pub partial: f64,
    /// The allgather (communication — the paper's subject).
    pub allgather: f64,
    /// Activation assembly + PJRT final forward.
    pub final_: f64,
    /// End-to-end leader-observed latency.
    pub total: f64,
}

/// Aggregated serving metrics.
#[derive(Debug, Clone)]
pub struct ServeMetrics {
    pub timings: Vec<RequestTiming>,
    /// Requests (batches) per second over the measured window.
    pub throughput: f64,
}

impl ServeMetrics {
    /// Build from per-request timings and the window wall time.
    pub fn new(timings: Vec<RequestTiming>, window_secs: f64) -> ServeMetrics {
        let n = timings.len();
        ServeMetrics {
            timings,
            throughput: if window_secs > 0.0 { n as f64 / window_secs } else { 0.0 },
        }
    }

    fn series(&self, f: impl Fn(&RequestTiming) -> f64) -> Vec<f64> {
        self.timings.iter().map(f).collect()
    }

    /// Latency summary of end-to-end request times.
    pub fn latency(&self) -> Option<Summary> {
        Summary::of(&self.series(|t| t.total))
    }

    /// Summary of time spent in the allgather.
    pub fn allgather(&self) -> Option<Summary> {
        Summary::of(&self.series(|t| t.allgather))
    }

    /// Fraction of total time spent communicating (mean over requests).
    pub fn comm_fraction(&self) -> f64 {
        let tot: f64 = self.series(|t| t.total).iter().sum();
        let ag: f64 = self.series(|t| t.allgather).iter().sum();
        if tot > 0.0 {
            ag / tot
        } else {
            0.0
        }
    }

    /// Human-readable report block.
    pub fn table(&self) -> String {
        use crate::util::fmt::seconds;
        let mut out = String::new();
        if let Some(l) = self.latency() {
            out.push_str(&format!(
                "latency  p50 {}  p90 {}  p99 {}  max {}\n",
                seconds(l.p50),
                seconds(l.p90),
                seconds(l.p99),
                seconds(l.max)
            ));
        }
        if let Some(a) = self.allgather() {
            out.push_str(&format!(
                "allgather p50 {}  p90 {}  (comm fraction {:.1}%)\n",
                seconds(a.p50),
                seconds(a.p90),
                100.0 * self.comm_fraction()
            ));
        }
        out.push_str(&format!("throughput {:.1} batches/s\n", self.throughput));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(total: f64, ag: f64) -> RequestTiming {
        RequestTiming { partial: 0.0, allgather: ag, final_: 0.0, total }
    }

    #[test]
    fn throughput_and_fractions() {
        let m = ServeMetrics::new(vec![t(0.1, 0.05), t(0.1, 0.05)], 2.0);
        assert_eq!(m.throughput, 1.0);
        assert!((m.comm_fraction() - 0.5).abs() < 1e-12);
        let l = m.latency().unwrap();
        assert!((l.p50 - 0.1).abs() < 1e-12);
        assert!(m.table().contains("throughput"));
    }

    #[test]
    fn empty_metrics_dont_panic() {
        let m = ServeMetrics::new(vec![], 0.0);
        assert!(m.latency().is_none());
        assert_eq!(m.comm_fraction(), 0.0);
        let _ = m.table();
    }
}
