//! The Layer-3 serving coordinator: tensor-parallel inference with the
//! paper's allgather on the request hot path.
//!
//! Topology-placed worker threads each own one shard of the TP-MLP
//! (`W1` column shard + replicated `W2`) and a **private PJRT engine**
//! (the `xla` crate's client is `!Send`, so engines are constructed inside
//! each worker thread). Per batched request:
//!
//! 1. the leader broadcasts the input batch to all workers;
//! 2. every worker runs `partial_fwd` (the AOT artifact embedding the
//!    Pallas matmul+GeLU kernel) on its shard via PJRT;
//! 3. the workers **allgather** the partial activations with the selected
//!    algorithm — this is where the locality-aware Bruck earns its keep;
//! 4. every worker assembles `h_full` and runs `final_fwd`; worker 0
//!    returns the output.
//!
//! Python never runs here: the artifacts were compiled by `make artifacts`.
//!
//! [`params`] recreates the Python side's deterministic parameters so the
//! whole pipeline is verified against an in-Rust reference forward pass —
//! the end-to-end correctness proof that all three layers compose.

pub mod metrics;
pub mod params;
pub mod server;

pub use metrics::ServeMetrics;
pub use params::ModelParams;
pub use server::{serve, serve_rps, RpsConfig, RpsReport, ServeConfig, ServeReport, RS_SHARD_ELEMS};
