//! The serving loop: batched tensor-parallel inference over the mini-MPI
//! with PJRT compute and a **fused, zero-copy** collective hot path.
//!
//! Every chunk of `fuse_batch` requests executes ONE fused schedule
//! ([`crate::collectives::FusedPlan`]): the chunk's allgathers (plus any
//! synthetic reduce-scatter shards and the consensus allreduce) are
//! round-merged and message-coalesced, so the coordinator pays one wire
//! message where sequential execution pays one per collective. The hot
//! path is zero-copy: the worker's buffers become segments of a composite
//! [`IoView`]/[`IoViewMut`] and the schedule executes in place over them,
//! with no staging copies per chunk (`ServeConfig::staged` keeps the
//! copying path as a baseline and conformance oracle).
//!
//! With `ServeConfig::pipeline` chunks are software-pipelined: chunk `c`'s
//! fused collective is begun, chunk `c-1`'s final projections run while it
//! is in flight, and only then are chunk `c`'s results collected. On the
//! proc backend the pool processes genuinely overlap the collective with
//! the parent's compute ([`PoolGate::begin_exchange`] /
//! [`PoolGate::finish_exchange`]); on the sim backend the execute is
//! synchronous, so the pipeline is structural only and the win comes from
//! the zero-copy views. Consensus probes then ride TWO chunks behind
//! (chunk `c`'s probes are produced while chunk `c+1` is already on the
//! wire, so the earliest collective that can carry them is chunk `c+2`'s);
//! the drain after the loop sums whatever is still pending, so every
//! request is verified either way.
//!
//! [`serve_rps`] is the artifact-free twin of [`serve`]: the same chunk
//! structure and fused hot path under a synthetic heavy load, measuring
//! end-to-end requests/sec of the staged serial baseline against the
//! zero-copy pipelined path on the same shape and backend.
//!
//! [`IoView`]: crate::collectives::IoView
//! [`IoViewMut`]: crate::collectives::IoViewMut
//! [`PoolGate::begin_exchange`]: crate::transport::PoolGate::begin_exchange
//! [`PoolGate::finish_exchange`]: crate::transport::PoolGate::finish_exchange

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use crate::collectives::{self, Algorithm, FuseSpec, OpKind, Shape};
use crate::comm::{as_bytes, copy_into, Comm, CommWorld, Timing};
use crate::coordinator::metrics::{RequestTiming, ServeMetrics};
use crate::coordinator::params::{max_abs_diff, ModelParams};
use crate::error::{Error, Result};
use crate::runtime::{Engine, Executable, Manifest};
use crate::topology::Topology;
use crate::trace::TraceSummary;
use crate::transport::{Backend, DType, PoolGate, ProcConfig, ProcJob, ProcPool};

/// Configuration of one serving run.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Directory holding `manifest.json` + `*.hlo.txt` (from `make artifacts`).
    pub artifact_dir: PathBuf,
    /// Allgather algorithm on the activation path.
    pub algo: Algorithm,
    /// Number of locality regions the TP workers span (must divide tp).
    pub regions: usize,
    /// Measured batched requests.
    pub requests: usize,
    /// Unmeasured warmup requests.
    pub warmup: usize,
    /// Verify outputs against the in-Rust reference forward.
    pub check: bool,
    /// Use the fused `gathered_matmul` artifact: the final projection
    /// consumes the allgather's rank-order buffer directly, skipping the
    /// `h_full` assembly pass (perf pass, L2/L1 fusion).
    pub fused: bool,
    /// Cross-worker output consensus: a planned allreduce (two f32 probes
    /// per request, riding the fused schedule behind the requests that
    /// produced them) sums an output fingerprint across workers; any
    /// worker whose projection diverged breaks the `p·x` identity and
    /// fails verification. Skipped when the topology admits no allreduce
    /// plan (unsupported shape / topology preconditions); genuine plan
    /// failures propagate.
    pub consensus: bool,
    /// Request micro-batch size `K`: the serving loop processes requests
    /// in chunks of `K`, executing the chunk's `K` allgathers (plus the
    /// consensus allreduce) as one fused, coalesced schedule. `1` fuses
    /// only the allgather with the consensus allreduce.
    pub fuse_batch: usize,
    /// Execute the fused schedule through the staging-copy path (compose
    /// the chunk's buffers into one contiguous input, execute, split the
    /// output back out) instead of the zero-copy segmented views. The
    /// baseline and conformance oracle for the view path; no effect on
    /// the proc backend, whose gate exchange is composite bytes either
    /// way.
    pub staged: bool,
    /// Software-pipeline the chunks: overlap chunk `c-1`'s final
    /// projections with chunk `c`'s in-flight fused collective (true
    /// compute/communication overlap on the proc backend). `false` runs
    /// the phases of each chunk back to back.
    pub pipeline: bool,
    /// Synthetic reduce-scatter shards riding each chunk's fused schedule
    /// ([`RS_SHARD_ELEMS`] elements each, exact-sum verified). Exercises
    /// reduce ops inside the fused serving schedule; `0` disables.
    pub rs_shards: usize,
    /// Backend the fused collective hot path executes on. [`Backend::Sim`]
    /// runs the fused schedule over in-process thread mailboxes;
    /// [`Backend::Proc`] spawns a persistent [`ProcPool`] (one OS process
    /// per TP worker) before the serving threads start, ships the fused
    /// schedule to it once, and every chunk's collective crosses real
    /// process boundaries over shm rings and Unix sockets via a
    /// [`PoolGate`] exchange.
    pub collective_backend: Backend,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            artifact_dir: Manifest::default_dir(),
            // The model-tuned dispatcher plans whatever the cost model says
            // is cheapest for the worker topology and activation shape.
            algo: Algorithm::ModelTuned,
            regions: 2,
            requests: 16,
            warmup: 2,
            check: true,
            fused: false,
            consensus: true,
            fuse_batch: 1,
            staged: false,
            pipeline: true,
            rs_shards: 0,
            collective_backend: Backend::Sim,
        }
    }
}

/// Outcome of a serving run.
#[derive(Debug)]
pub struct ServeReport {
    pub metrics: ServeMetrics,
    /// True if every checked output matched the reference within tolerance.
    pub verified: bool,
    /// Max |err| observed against the reference.
    pub max_err: f32,
    /// Traffic accounting over the whole run.
    pub trace: TraceSummary,
    /// First few values of the last response (for quickstart printing).
    pub output_sample: Vec<f32>,
    /// Model dimensions served.
    pub tp: usize,
    pub params: usize,
}

/// Run the TP serving loop. One thread per TP worker; worker 0 doubles as
/// the leader (generates/broadcasts batches, records metrics).
pub fn serve(cfg: &ServeConfig) -> Result<ServeReport> {
    // Validate artifacts & dims on the main thread for clean errors.
    let manifest = Manifest::load(&cfg.artifact_dir)?;
    let dims = manifest.model;
    let tp = dims.tp;
    if cfg.regions == 0 || tp % cfg.regions != 0 {
        return Err(Error::Coordinator(format!(
            "regions={} must divide tp={tp}",
            cfg.regions
        )));
    }
    let topo = Topology::regions(cfg.regions, tp / cfg.regions);
    let fuse_batch = cfg.fuse_batch.max(1);

    // With the proc collective backend the pool and its fused schedule are
    // fixed BEFORE the worker threads exist: replicate the serving loop's
    // constituent decision comm-free, spawn the pool (workers handshake
    // once), ship the fused schedule to it once, and hand every worker
    // thread a gate onto the shared pool. Each chunk then crosses real
    // OS-process boundaries while planning costs nothing per request.
    let (gate, gate_consensus) = if cfg.collective_backend == Backend::Proc {
        let machine = crate::model::MachineParams::lassen();
        let n_gather = dims.batch * dims.hidden_shard();
        let (specs, wc) = serving_pool_specs(
            &topo,
            cfg.algo,
            n_gather,
            fuse_batch,
            cfg.rs_shards,
            cfg.consensus,
            &machine,
        )?;
        let mut pool =
            ProcPool::spawn(cfg.regions, tp / cfg.regions, machine.name, &ProcConfig::default())?;
        let sid = pool.load(&ProcJob::Fused { specs, dtype: DType::F32 })?;
        (Some(Arc::new(PoolGate::new(pool, sid))), wc)
    } else {
        (None, false)
    };

    let cfgw = cfg.clone();
    let start = Instant::now();
    let run = CommWorld::run(&topo, Timing::Wallclock, move |c| -> Result<WorkerOut> {
        worker_loop(c, &cfgw, gate.as_deref(), gate_consensus)
    });
    let window = start.elapsed().as_secs_f64();

    // Worker 0 carries the report; surface any worker's error.
    let mut out0 = None;
    for (rank, res) in run.results.into_iter().enumerate() {
        match res {
            Ok(o) => {
                if rank == 0 {
                    out0 = Some(o);
                }
            }
            Err(e) => {
                return Err(Error::Coordinator(format!("worker {rank}: {e}")));
            }
        }
    }
    let out0 = out0.expect("worker 0 always present");
    Ok(ServeReport {
        metrics: ServeMetrics::new(out0.timings, window),
        verified: out0.verified && out0.consensus_ok,
        max_err: out0.max_err,
        trace: run.trace,
        output_sample: out0.sample,
        tp,
        params: dims.params,
    })
}

struct WorkerOut {
    timings: Vec<RequestTiming>,
    verified: bool,
    /// True unless the consensus allreduce caught divergent outputs.
    consensus_ok: bool,
    max_err: f32,
    sample: Vec<f32>,
}

/// Compare a summed fingerprint against `p × mine` (float reassociation
/// slack allowed); clears `ok` on divergence.
fn check_probes(sum: &[f32], mine: &[f32], pf: f32, ok: &mut bool) {
    for (got, m) in sum.iter().zip(mine) {
        if (got - pf * m).abs() > 1e-3 * (1.0 + (pf * m).abs()) {
            *ok = false;
        }
    }
}

/// Element count of each synthetic reduce-scatter shard riding the fused
/// serving schedule ([`ServeConfig::rs_shards`] of them per chunk). Small
/// on purpose: the shards put reduce ops on the fused serving hot path,
/// they are not a bandwidth payload.
pub const RS_SHARD_ELEMS: usize = 16;

/// Deterministic reduce-scatter input for one `(rank, chunk, shard)`:
/// `RS_SHARD_ELEMS·p` small integers, exact in f32, so the scattered sums
/// verify exactly.
fn rs_input(rank: usize, chunk: usize, shard: usize, p: usize) -> Vec<f32> {
    (0..RS_SHARD_ELEMS * p)
        .map(|i| ((rank * 31 + chunk * 7 + shard * 13 + i) % 64) as f32)
        .collect()
}

/// The shard [`rs_input`] scatters to `rank`: element `i` is the exact
/// sum over all ranks of their input at offset `rank·RS_SHARD_ELEMS + i`.
fn rs_expected(rank: usize, chunk: usize, shard: usize, p: usize) -> Vec<f32> {
    (0..RS_SHARD_ELEMS)
        .map(|i| {
            let off = rank * RS_SHARD_ELEMS + i;
            (0..p).map(|r| ((r * 31 + chunk * 7 + shard * 13 + off) % 64) as f32).sum()
        })
        .collect()
}

/// Reduce-scatter algorithm for the synthetic serving shards: the
/// locality-aware builder when it admits this topology, ring otherwise.
/// Probing the builder (instead of trying and catching at fuse time)
/// keeps the decision deterministic and identical between the live
/// per-worker planner and the comm-free pool-spec path.
fn serving_rs_algo(view: &collectives::schedule::WorldView) -> &'static str {
    let esz = std::mem::size_of::<f32>();
    let probe =
        collectives::schedule::build_reduce_scatter("loc-aware", view, 0, RS_SHARD_ELEMS, esz);
    if probe.is_ok() {
        "loc-aware"
    } else {
        "ring"
    }
}

/// Plan the chunk's fused schedule: `k` allgathers (one per request of the
/// chunk), `rs_shards` synthetic reduce-scatters, plus — when consensus is
/// requested and the topology admits it — one `2k`-probe consensus
/// allreduce. Returns the plan and whether the consensus constituent is
/// on board.
///
/// Only failures of the consensus constituent *itself* (its schedule
/// builder rejecting the shape / topology) downgrade to a consensus-free
/// plan — genuine plan failures propagate. (The old serving loop
/// swallowed every consensus planning error with `.ok()`.)
fn plan_serving_fused(
    c: &Comm,
    algo: Algorithm,
    n_gather: usize,
    k: usize,
    rs_shards: usize,
    consensus: bool,
) -> Result<(collectives::FusedPlan<f32>, bool)> {
    let view = collectives::schedule::WorldView::from_comm(c);
    let rs_algo = serving_rs_algo(&view);
    let mut specs: Vec<FuseSpec> =
        (0..k).map(|_| FuseSpec::new(OpKind::Allgather, algo.name(), n_gather)).collect();
    specs.extend(
        (0..rs_shards).map(|_| FuseSpec::new(OpKind::ReduceScatter, rs_algo, RS_SHARD_ELEMS)),
    );
    if consensus {
        specs.push(FuseSpec::new(OpKind::Allreduce, "loc-aware", 2 * k));
        match collectives::plan_fused::<f32>(c, &specs) {
            Ok(p) => return Ok((p, true)),
            Err(e) => {
                specs.pop();
                // Downgrade to consensus-free serving ONLY when the
                // consensus constituent itself rejects this topology /
                // shape (its builder fails, e.g. non-power-of-two worker
                // groups). Every other failure — an allgather problem, a
                // fusion-consistency failure — propagates. (The old loop
                // swallowed all of these with `.ok()`.)
                let probe = collectives::schedule::build_allreduce(
                    "loc-aware",
                    &view,
                    c.rank(),
                    2 * k,
                    std::mem::size_of::<f32>(),
                );
                if probe.is_ok() {
                    return Err(e);
                }
            }
        }
    }
    Ok((collectives::plan_fused::<f32>(c, &specs)?, false))
}

/// Comm-free replica of [`plan_serving_fused`]'s constituent decision for
/// the proc backend: the pool's fused job must be fixed before any worker
/// thread exists, so the same try-with-consensus / probe-the-builder
/// downgrade logic runs against a [`WorldView`] of the topology instead
/// of a live communicator. Returns the fused specs and whether the
/// consensus allreduce is on board.
///
/// [`WorldView`]: collectives::schedule::WorldView
#[allow(clippy::too_many_arguments)]
fn serving_pool_specs(
    topo: &Topology,
    algo: Algorithm,
    n_gather: usize,
    k: usize,
    rs_shards: usize,
    consensus: bool,
    machine: &crate::model::MachineParams,
) -> Result<(Vec<FuseSpec>, bool)> {
    use crate::collectives::{fuse, schedule};
    let esz = std::mem::size_of::<f32>();
    let view = schedule::WorldView::world(topo);
    let rs_algo = serving_rs_algo(&view);
    let mut specs: Vec<FuseSpec> =
        (0..k).map(|_| FuseSpec::new(OpKind::Allgather, algo.name(), n_gather)).collect();
    specs.extend(
        (0..rs_shards).map(|_| FuseSpec::new(OpKind::ReduceScatter, rs_algo, RS_SHARD_ELEMS)),
    );
    if consensus {
        specs.push(FuseSpec::new(OpKind::Allreduce, "loc-aware", 2 * k));
        match fuse::fuse_world(&specs, &view, esz, machine) {
            Ok(_) => return Ok((specs, true)),
            Err(e) => {
                specs.pop();
                // Same downgrade contract as plan_serving_fused: only the
                // consensus constituent's own builder rejecting this
                // topology / shape drops it from the plan.
                let probe = schedule::build_allreduce("loc-aware", &view, 0, 2 * k, esz);
                if probe.is_ok() {
                    return Err(e);
                }
            }
        }
    }
    fuse::fuse_world(&specs, &view, esz, machine)?;
    Ok((specs, false))
}

/// One chunk's fused collective, split into `begin`/`finish` so the
/// caller can overlap compute with the in-flight exchange. Owns the
/// persistent composite byte buffers the proc path reuses across chunks
/// (the per-chunk input delta is built with bulk byte reinterprets — no
/// per-element encode/decode on the hot path).
struct ChunkCollective<'a> {
    k: usize,
    rs_shards: usize,
    /// Per-request allgather input elements (`b·hs` when serving a model).
    shard_elems: usize,
    p: usize,
    with_consensus: bool,
    staged: bool,
    gate: Option<&'a PoolGate>,
    fplan: Option<collectives::FusedPlan<f32>>,
    inbytes: Vec<u8>,
    outbytes: Vec<u8>,
}

impl ChunkCollective<'_> {
    /// Start the chunk's fused collective. Proc backend: serialize the
    /// composite input (constituent order: `k` allgather shards, the
    /// reduce-scatter shards, then the consensus probes) and ship it; the
    /// collective is in flight when this returns. Sim backend: execute
    /// synchronously — in place over segmented views of the caller's
    /// buffers, or through the staging-copy path when `staged`.
    #[allow(clippy::too_many_arguments)]
    fn begin(
        &mut self,
        rank: usize,
        h_parts: &[Vec<f32>],
        rs_in: &[Vec<f32>],
        probes_in: &[f32],
        gathered: &mut [Vec<f32>],
        rs_out: &mut [Vec<f32>],
        probe_sum: &mut [f32],
    ) -> Result<()> {
        if let Some(g) = self.gate {
            self.inbytes.clear();
            for hp in h_parts {
                self.inbytes.extend_from_slice(as_bytes(hp));
            }
            for ri in rs_in {
                self.inbytes.extend_from_slice(as_bytes(ri));
            }
            if self.with_consensus {
                self.inbytes.extend_from_slice(as_bytes(probes_in));
            }
            return g.begin_exchange(rank, &self.inbytes);
        }
        let fplan = self.fplan.as_mut().expect("sim path planned at startup");
        let mut in_refs: Vec<&[f32]> = Vec::with_capacity(self.k + self.rs_shards + 1);
        in_refs.extend(h_parts.iter().map(|v| v.as_slice()));
        in_refs.extend(rs_in.iter().map(|v| v.as_slice()));
        let mut out_refs: Vec<&mut [f32]> = Vec::with_capacity(self.k + self.rs_shards + 1);
        out_refs.extend(gathered.iter_mut().map(|v| v.as_mut_slice()));
        out_refs.extend(rs_out.iter_mut().map(|v| v.as_mut_slice()));
        if self.with_consensus {
            in_refs.push(probes_in);
            out_refs.push(probe_sum);
        }
        if self.staged {
            fplan.execute(&in_refs, &mut out_refs)
        } else {
            fplan.execute_view(&in_refs, &mut out_refs)
        }
    }

    /// Collect the chunk's results. Proc backend: wait for the pool and
    /// split the composite output back out with bulk byte reinterprets.
    /// Sim backend: no-op (`begin` already executed into the buffers).
    fn finish(
        &mut self,
        rank: usize,
        gathered: &mut [Vec<f32>],
        rs_out: &mut [Vec<f32>],
        probe_sum: &mut [f32],
    ) -> Result<()> {
        let Some(g) = self.gate else { return Ok(()) };
        g.finish_exchange(rank, &mut self.outbytes)?;
        let gather_bytes = self.shard_elems * self.p * 4;
        let rs_bytes = RS_SHARD_ELEMS * 4;
        let want = self.k * gather_bytes
            + self.rs_shards * rs_bytes
            + if self.with_consensus { 2 * self.k * 4 } else { 0 };
        if self.outbytes.len() != want {
            return Err(Error::Coordinator(format!(
                "fused output is {} bytes, expected {want}",
                self.outbytes.len()
            )));
        }
        let mut off = 0usize;
        for gj in gathered.iter_mut() {
            if !copy_into(&self.outbytes[off..off + gather_bytes], gj.as_mut_slice()) {
                return Err(Error::Coordinator("gathered block size mismatch".into()));
            }
            off += gather_bytes;
        }
        for rj in rs_out.iter_mut() {
            if !copy_into(&self.outbytes[off..off + rs_bytes], rj.as_mut_slice()) {
                return Err(Error::Coordinator("reduce-scatter shard size mismatch".into()));
            }
            off += rs_bytes;
        }
        if self.with_consensus && !copy_into(&self.outbytes[off..], probe_sum) {
            return Err(Error::Coordinator("consensus probe window size mismatch".into()));
        }
        Ok(())
    }
}

/// A completed chunk whose final projections are deferred until its
/// successor's collective is in flight.
struct PendingFinals {
    chunk: usize,
    t_partials: Vec<f64>,
    t_collective: f64,
}

/// Read-only context of the final-projection phase.
struct FinalsEnv<'a> {
    rank: usize,
    k: usize,
    b: usize,
    hs: usize,
    h: usize,
    p: usize,
    total_reqs: usize,
    warmup: usize,
    check: bool,
    with_consensus: bool,
    final_: &'a Executable,
    fused_final: Option<&'a Executable>,
    params: &'a ModelParams,
}

/// Final projections of one completed chunk: consume its gathered bank,
/// record per-request timings and reference checks, and enqueue its
/// consensus probes for the next collective that can carry them. A
/// request's recorded `total` is the sum of its three phases (its share
/// of the fused collective is `t_collective / k`), which stays meaningful
/// when the phases of adjacent chunks overlap in wall time.
fn run_finals(
    st: PendingFinals,
    gathered: &[Vec<f32>],
    env: &FinalsEnv<'_>,
    pending_probes: &mut VecDeque<Vec<f32>>,
    out: &mut WorkerOut,
) -> Result<()> {
    let mut probes_now = vec![0f32; 2 * env.k];
    for (j, gj) in gathered.iter().enumerate() {
        let req = st.chunk * env.k + j;
        let t0 = Instant::now();
        let y = if let Some(ff) = env.fused_final {
            ff.run_f32(&[gj, &env.params.w2])?
        } else {
            let (b, hs, h) = (env.b, env.hs, env.h);
            let mut h_full = vec![0f32; b * h];
            for i in 0..env.p {
                let blk = &gj[i * b * hs..(i + 1) * b * hs];
                for row in 0..b {
                    let dst = row * h + i * hs;
                    h_full[dst..dst + hs].copy_from_slice(&blk[row * hs..(row + 1) * hs]);
                }
            }
            env.final_.run_f32(&[&h_full, &env.params.w2])?
        };
        let t_final = t0.elapsed().as_secs_f64();
        probes_now[2 * j] = y[0];
        probes_now[2 * j + 1] = y[y.len() - 1];

        if env.rank == 0 && req < env.total_reqs {
            if env.check {
                let xr = env.params.example_batch(req as f32 + 1.0);
                let want = env.params.reference_forward(&xr);
                let err = max_abs_diff(&y, &want);
                out.max_err = out.max_err.max(err);
                if err > 1e-3 {
                    out.verified = false;
                }
            }
            if req + 1 == env.total_reqs {
                out.sample = y.iter().take(8).copied().collect();
            }
            if req >= env.warmup {
                let share = st.t_collective / env.k as f64;
                out.timings.push(RequestTiming {
                    partial: st.t_partials[j],
                    allgather: share,
                    final_: t_final,
                    total: st.t_partials[j] + share + t_final,
                });
            }
        }
    }
    if env.with_consensus {
        pending_probes.push_back(probes_now);
    }
    Ok(())
}

fn worker_loop(
    c: &mut Comm,
    cfg: &ServeConfig,
    gate: Option<&PoolGate>,
    gate_consensus: bool,
) -> Result<WorkerOut> {
    // Each worker owns a private PJRT engine (the client is !Send).
    let engine = Engine::load(&cfg.artifact_dir)?;
    let dims = engine.manifest.model;
    let (b, hs, h) = (dims.batch, dims.hidden_shard(), dims.d_hidden);
    let params = ModelParams::generate(dims, 0.0);
    let w1s = params.w1_shard(c.rank());
    let partial = engine.executable("partial_fwd")?;
    let final_ = engine.executable("final_fwd")?;
    let fused_final = if cfg.fused {
        Some(engine.executable("fused_final")?)
    } else {
        None
    };

    let total_reqs = cfg.warmup + cfg.requests;
    let k = cfg.fuse_batch.max(1);
    let rs_shards = cfg.rs_shards;
    let p = c.size();
    let pf = p as f32;

    // The fused plan is built ONCE per worker: every request moves the
    // same (batch, hidden_shard) activation shape, so the serving loop is
    // the persistent-plan use case — all setup (schedule fusion, message
    // coalescing, tags, scratch) amortizes across all requests and the
    // hot path executes one coalesced schedule per chunk over reused
    // caller-owned buffers. On the proc backend the schedule already
    // lives in the worker pool (loaded once before these threads
    // started), so nothing is planned here at all.
    let (fplan, with_consensus) = match gate {
        Some(_) => (None, gate_consensus),
        None => {
            let (plan, wc) = plan_serving_fused(c, cfg.algo, b * hs, k, rs_shards, cfg.consensus)?;
            (Some(plan), wc)
        }
    };

    // The drain allreduce verifies probes the fused consensus could no
    // longer carry after the final chunk.
    let mut drain_plan = if with_consensus {
        Some(collectives::plan_allreduce::<f32>("loc-aware", c, Shape::elems(2 * k))?)
    } else {
        None
    };

    let mut coll = ChunkCollective {
        k,
        rs_shards,
        shard_elems: b * hs,
        p,
        with_consensus,
        staged: cfg.staged,
        gate,
        fplan,
        inbytes: Vec::new(),
        outbytes: Vec::new(),
    };

    // Double-buffered result banks: with pipelining, chunk c's collective
    // fills bank c % 2 while chunk c-1's deferred finals still read bank
    // (c-1) % 2.
    let mut gathered: [Vec<Vec<f32>>; 2] = [
        (0..k).map(|_| vec![0f32; b * hs * p]).collect(),
        (0..k).map(|_| vec![0f32; b * hs * p]).collect(),
    ];
    let mut rs_out: [Vec<Vec<f32>>; 2] = [
        (0..rs_shards).map(|_| vec![0f32; RS_SHARD_ELEMS]).collect(),
        (0..rs_shards).map(|_| vec![0f32; RS_SHARD_ELEMS]).collect(),
    ];
    let mut probe_sum = vec![0f32; 2 * k];
    // Probes are produced by finals and consumed by the next collective
    // that can carry them: one chunk behind serially, two when pipelined
    // (finals of chunk c run after chunk c+1's collective began).
    let mut pending_probes: VecDeque<Vec<f32>> = VecDeque::new();
    let zero_probes = vec![0f32; 2 * k];

    let env = FinalsEnv {
        rank: c.rank(),
        k,
        b,
        hs,
        h,
        p,
        total_reqs,
        warmup: cfg.warmup,
        check: cfg.check,
        with_consensus,
        final_,
        fused_final,
        params: &params,
    };
    let mut out = WorkerOut {
        timings: Vec::with_capacity(total_reqs.saturating_sub(cfg.warmup)),
        verified: true,
        consensus_ok: true,
        max_err: 0f32,
        sample: Vec::new(),
    };
    let mut deferred: Option<PendingFinals> = None;

    // Chunked request loop. The final chunk is padded with zero batches so
    // every fused execution is a full collective; padded requests are
    // computed but never recorded or checked.
    let chunks = total_reqs.div_ceil(k);
    for chunk in 0..chunks {
        let bank = chunk % 2;
        // Phase 1: request ingress + PJRT partial forward per request.
        let mut h_parts: Vec<Vec<f32>> = Vec::with_capacity(k);
        let mut t_partials = vec![0f64; k];
        for (j, t_partial) in t_partials.iter_mut().enumerate() {
            let req = chunk * k + j;
            // Leader generates the batch and broadcasts it (request ingress).
            let x = if c.rank() == 0 {
                let seed = if req < total_reqs { req as f32 + 1.0 } else { 0.0 };
                Some(params.example_batch(seed))
            } else {
                None
            };
            let x = collectives::primitives::bcast(c, x, 0)?;
            let t0 = Instant::now();
            let h_part = partial.run_f32(&[&x, &w1s])?;
            *t_partial = t0.elapsed().as_secs_f64();
            h_parts.push(h_part);
        }
        let rs_in: Vec<Vec<f32>> =
            (0..rs_shards).map(|s| rs_input(c.rank(), chunk, s, p)).collect();

        // Phase 2: begin the chunk's fused collective (the chunk's k
        // allgathers, the reduce-scatter shards, and the oldest pending
        // consensus probes, coalesced into shared wire messages).
        let probes_in = pending_probes.pop_front();
        let t1 = Instant::now();
        coll.begin(
            c.rank(),
            &h_parts,
            &rs_in,
            probes_in.as_deref().unwrap_or(&zero_probes),
            &mut gathered[bank],
            &mut rs_out[bank],
            &mut probe_sum,
        )?;
        let mut t_coll = t1.elapsed().as_secs_f64();

        // Pipeline overlap: the previous chunk's final projections run
        // while this chunk's collective is on the wire.
        if let Some(st) = deferred.take() {
            let prev_bank = st.chunk % 2;
            run_finals(st, &gathered[prev_bank], &env, &mut pending_probes, &mut out)?;
        }

        let t2 = Instant::now();
        coll.finish(c.rank(), &mut gathered[bank], &mut rs_out[bank], &mut probe_sum)?;
        t_coll += t2.elapsed().as_secs_f64();

        // Verify whatever this collective carried.
        if let Some(prev) = probes_in {
            check_probes(&probe_sum, &prev, pf, &mut out.consensus_ok);
        }
        for (s, rj) in rs_out[bank].iter().enumerate() {
            if rj != &rs_expected(c.rank(), chunk, s, p) {
                out.verified = false;
            }
        }

        // Phase 3: final projections — deferred one chunk when pipelined.
        let st = PendingFinals { chunk, t_partials, t_collective: t_coll };
        if cfg.pipeline {
            deferred = Some(st);
        } else {
            run_finals(st, &gathered[bank], &env, &mut pending_probes, &mut out)?;
        }
    }
    if let Some(st) = deferred.take() {
        let prev_bank = st.chunk % 2;
        run_finals(st, &gathered[prev_bank], &env, &mut pending_probes, &mut out)?;
    }

    // Drain: probes produced after the last collective that could carry
    // them (one chunk's worth serially, two when pipelined).
    if let Some(dp) = drain_plan.as_mut() {
        while let Some(prev) = pending_probes.pop_front() {
            dp.execute(&prev, &mut probe_sum)?;
            check_probes(&probe_sum, &prev, pf, &mut out.consensus_ok);
        }
    }

    Ok(out)
}

// ---------------------------------------------------------------------------
// Synthetic serving throughput (`locag e2e --measure-rps`)
// ---------------------------------------------------------------------------

/// Configuration of the synthetic serving-throughput mode: the same chunk
/// structure and fused collective hot path as [`serve`], under a
/// deterministic generated load instead of PJRT compute — so it needs no
/// compiled artifacts and measures the transport/staging path itself.
#[derive(Debug, Clone)]
pub struct RpsConfig {
    /// Locality regions of the worker topology.
    pub regions: usize,
    /// Workers per region (`p = regions · ppr`).
    pub ppr: usize,
    /// Measured requests.
    pub requests: usize,
    /// Unmeasured warmup requests (rounded down to whole chunks).
    pub warmup: usize,
    /// Requests per fused chunk.
    pub fuse_batch: usize,
    /// Synthetic reduce-scatter shards per chunk (see
    /// [`ServeConfig::rs_shards`]).
    pub rs_shards: usize,
    /// Per-request allgather input elements per worker.
    pub n_gather: usize,
    /// Allgather algorithm on the activation path.
    pub algo: Algorithm,
    /// Carry the consensus allreduce (see [`ServeConfig::consensus`]).
    pub consensus: bool,
    /// Backend the fused hot path executes on.
    pub backend: Backend,
}

impl Default for RpsConfig {
    fn default() -> Self {
        RpsConfig {
            regions: 2,
            ppr: 2,
            requests: 64,
            warmup: 8,
            fuse_batch: 4,
            rs_shards: 2,
            n_gather: 4096,
            algo: Algorithm::ModelTuned,
            consensus: true,
            backend: Backend::Sim,
        }
    }
}

/// Outcome of [`serve_rps`]: measured end-to-end requests/sec of the
/// staged serial baseline vs the zero-copy pipelined hot path, same
/// shape, load and backend.
#[derive(Debug)]
pub struct RpsReport {
    /// World size.
    pub p: usize,
    /// Fused chunks per pass.
    pub chunks: usize,
    /// Measured requests per pass.
    pub requests: usize,
    /// Requests/sec, staging copies + back-to-back chunk phases.
    pub rps_staged: f64,
    /// Requests/sec, segmented views + software-pipelined chunks.
    pub rps_zero_copy: f64,
    /// `rps_zero_copy / rps_staged`.
    pub speedup: f64,
    /// True if both passes verified every gathered block, reduce-scatter
    /// shard and consensus probe.
    pub verified: bool,
}

/// Measure serving throughput before/after the zero-copy + pipelining
/// work: one pass with staging copies and strictly serial chunk phases,
/// one pass with segmented views and cross-chunk software pipelining.
/// Every byte both passes move is still verified (generated inputs have
/// closed-form gathered/scattered values).
pub fn serve_rps(cfg: &RpsConfig) -> Result<RpsReport> {
    if cfg.regions == 0 || cfg.ppr == 0 {
        return Err(Error::Coordinator("rps mode needs a non-empty topology".into()));
    }
    let (rps_staged, ok_staged) = rps_pass(cfg, true, false)?;
    let (rps_zero_copy, ok_zc) = rps_pass(cfg, false, true)?;
    let k = cfg.fuse_batch.max(1);
    Ok(RpsReport {
        p: cfg.regions * cfg.ppr,
        chunks: (cfg.warmup + cfg.requests).div_ceil(k),
        requests: cfg.requests,
        rps_staged,
        rps_zero_copy,
        speedup: rps_zero_copy / rps_staged.max(f64::MIN_POSITIVE),
        verified: ok_staged && ok_zc,
    })
}

/// One measured pass of the synthetic serving loop.
fn rps_pass(cfg: &RpsConfig, staged: bool, pipeline: bool) -> Result<(f64, bool)> {
    let topo = Topology::regions(cfg.regions, cfg.ppr);
    let k = cfg.fuse_batch.max(1);
    let (gate, gate_consensus) = if cfg.backend == Backend::Proc {
        let machine = crate::model::MachineParams::lassen();
        let (specs, wc) = serving_pool_specs(
            &topo,
            cfg.algo,
            cfg.n_gather,
            k,
            cfg.rs_shards,
            cfg.consensus,
            &machine,
        )?;
        let mut pool =
            ProcPool::spawn(cfg.regions, cfg.ppr, machine.name, &ProcConfig::default())?;
        let sid = pool.load(&ProcJob::Fused { specs, dtype: DType::F32 })?;
        (Some(Arc::new(PoolGate::new(pool, sid))), wc)
    } else {
        (None, false)
    };
    let cfgw = cfg.clone();
    let run = CommWorld::run(&topo, Timing::Wallclock, move |c| -> Result<(f64, bool)> {
        rps_worker_loop(c, &cfgw, staged, pipeline, gate.as_deref(), gate_consensus)
    });
    let mut out = None;
    for (rank, res) in run.results.into_iter().enumerate() {
        match res {
            Ok(o) => {
                if rank == 0 {
                    out = Some(o);
                }
            }
            Err(e) => return Err(Error::Coordinator(format!("rps worker {rank}: {e}"))),
        }
    }
    Ok(out.expect("worker 0 always present"))
}

/// Deterministic synthetic activation shard of `req` on `rank`.
fn rps_shard(rank: usize, req: usize, n: usize) -> Vec<f32> {
    (0..n).map(|i| ((rank * 131 + req * 17 + i) % 97) as f32).collect()
}

/// Synthetic final projection of one chunk: a full verification pass over
/// each request's gathered buffer (every rank's block must equal its
/// generator) standing in for the projection compute the real serving
/// loop overlaps, plus the consensus probes derived from it. The probes
/// are functions of the gathered (rank-identical) data, so the `p·x`
/// consensus identity holds exactly as in [`worker_loop`].
fn rps_finals(
    chunk: usize,
    n: usize,
    p: usize,
    gathered: &[Vec<f32>],
    with_consensus: bool,
    pending_probes: &mut VecDeque<Vec<f32>>,
    ok: &mut bool,
) {
    let k = gathered.len();
    let mut probes = vec![0f32; 2 * k];
    for (j, gj) in gathered.iter().enumerate() {
        let req = chunk * k + j;
        let mut sum = 0f64;
        for r in 0..p {
            let blk = &gj[r * n..(r + 1) * n];
            for (i, v) in blk.iter().enumerate() {
                if *v != ((r * 131 + req * 17 + i) % 97) as f32 {
                    *ok = false;
                }
                sum += *v as f64;
            }
        }
        probes[2 * j] = gj[0];
        // Integer-valued and < 1024, so exact in f32 and its p-fold
        // allreduce sum is exact too.
        probes[2 * j + 1] = (sum % 1024.0) as f32;
    }
    if with_consensus {
        pending_probes.push_back(probes);
    }
}

/// Synthetic twin of [`worker_loop`]: identical chunk structure, fused
/// hot path, probe FIFO and drain, with generated inputs in place of
/// PJRT. Returns worker-local (requests/sec, verified).
fn rps_worker_loop(
    c: &mut Comm,
    cfg: &RpsConfig,
    staged: bool,
    pipeline: bool,
    gate: Option<&PoolGate>,
    gate_consensus: bool,
) -> Result<(f64, bool)> {
    let p = c.size();
    let pf = p as f32;
    let k = cfg.fuse_batch.max(1);
    let n = cfg.n_gather;
    let rs_shards = cfg.rs_shards;
    let total_reqs = cfg.warmup + cfg.requests;

    let (fplan, with_consensus) = match gate {
        Some(_) => (None, gate_consensus),
        None => {
            let (plan, wc) = plan_serving_fused(c, cfg.algo, n, k, rs_shards, cfg.consensus)?;
            (Some(plan), wc)
        }
    };
    let mut drain_plan = if with_consensus {
        Some(collectives::plan_allreduce::<f32>("loc-aware", c, Shape::elems(2 * k))?)
    } else {
        None
    };
    let mut coll = ChunkCollective {
        k,
        rs_shards,
        shard_elems: n,
        p,
        with_consensus,
        staged,
        gate,
        fplan,
        inbytes: Vec::new(),
        outbytes: Vec::new(),
    };

    let mut gathered: [Vec<Vec<f32>>; 2] = [
        (0..k).map(|_| vec![0f32; n * p]).collect(),
        (0..k).map(|_| vec![0f32; n * p]).collect(),
    ];
    let mut rs_out: [Vec<Vec<f32>>; 2] = [
        (0..rs_shards).map(|_| vec![0f32; RS_SHARD_ELEMS]).collect(),
        (0..rs_shards).map(|_| vec![0f32; RS_SHARD_ELEMS]).collect(),
    ];
    let mut probe_sum = vec![0f32; 2 * k];
    let mut pending_probes: VecDeque<Vec<f32>> = VecDeque::new();
    let zero_probes = vec![0f32; 2 * k];
    let mut ok = true;
    let mut deferred: Option<(usize, usize)> = None;

    let chunks = total_reqs.div_ceil(k);
    let warm_chunks = (cfg.warmup / k).min(chunks);
    let mut t_measure = Instant::now();

    for chunk in 0..chunks {
        if chunk == warm_chunks {
            t_measure = Instant::now();
        }
        let bank = chunk % 2;
        let h_parts: Vec<Vec<f32>> =
            (0..k).map(|j| rps_shard(c.rank(), chunk * k + j, n)).collect();
        let rs_in: Vec<Vec<f32>> =
            (0..rs_shards).map(|s| rs_input(c.rank(), chunk, s, p)).collect();

        let probes_in = pending_probes.pop_front();
        coll.begin(
            c.rank(),
            &h_parts,
            &rs_in,
            probes_in.as_deref().unwrap_or(&zero_probes),
            &mut gathered[bank],
            &mut rs_out[bank],
            &mut probe_sum,
        )?;
        if let Some((pchunk, pbank)) = deferred.take() {
            rps_finals(
                pchunk,
                n,
                p,
                &gathered[pbank],
                with_consensus,
                &mut pending_probes,
                &mut ok,
            );
        }
        coll.finish(c.rank(), &mut gathered[bank], &mut rs_out[bank], &mut probe_sum)?;
        if let Some(prev) = probes_in {
            check_probes(&probe_sum, &prev, pf, &mut ok);
        }
        for (s, rj) in rs_out[bank].iter().enumerate() {
            if rj != &rs_expected(c.rank(), chunk, s, p) {
                ok = false;
            }
        }
        if pipeline {
            deferred = Some((chunk, bank));
        } else {
            rps_finals(chunk, n, p, &gathered[bank], with_consensus, &mut pending_probes, &mut ok);
        }
    }
    if let Some((pchunk, pbank)) = deferred.take() {
        rps_finals(pchunk, n, p, &gathered[pbank], with_consensus, &mut pending_probes, &mut ok);
    }
    if let Some(dp) = drain_plan.as_mut() {
        while let Some(prev) = pending_probes.pop_front() {
            dp.execute(&prev, &mut probe_sum)?;
            check_probes(&probe_sum, &prev, pf, &mut ok);
        }
    }

    let elapsed = t_measure.elapsed().as_secs_f64().max(f64::MIN_POSITIVE);
    let measured = total_reqs - warm_chunks * k;
    Ok((measured as f64 / elapsed, ok))
}

// Integration coverage (requires artifacts): rust/tests/coordinator_integration.rs

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rs_expected_is_the_column_sum_of_every_ranks_input() {
        let (p, chunk, shard) = (4, 3, 1);
        let inputs: Vec<Vec<f32>> = (0..p).map(|r| rs_input(r, chunk, shard, p)).collect();
        for me in 0..p {
            let want: Vec<f32> = (0..RS_SHARD_ELEMS)
                .map(|i| inputs.iter().map(|v| v[me * RS_SHARD_ELEMS + i]).sum())
                .collect();
            assert_eq!(rs_expected(me, chunk, shard, p), want);
        }
    }

    #[test]
    fn serving_pool_specs_order_gathers_then_scatters_then_consensus() {
        let topo = Topology::regions(2, 2);
        let machine = crate::model::MachineParams::lassen();
        let (k, rs) = (2, 3);
        let (specs, wc) =
            serving_pool_specs(&topo, Algorithm::ModelTuned, 64, k, rs, true, &machine)
                .expect("2x2 serving specs fuse");
        assert!(wc, "2x2 admits the loc-aware consensus allreduce");
        assert_eq!(specs.len(), k + rs + 1);
        assert!(specs[..k].iter().all(|s| s.op == OpKind::Allgather));
        assert!(specs[k..k + rs]
            .iter()
            .all(|s| s.op == OpKind::ReduceScatter && s.n == RS_SHARD_ELEMS));
        assert_eq!(specs[k + rs].op, OpKind::Allreduce);
        assert_eq!(specs[k + rs].n, 2 * k);
    }

    #[test]
    fn rps_sim_pass_verifies_both_paths() {
        let cfg = RpsConfig {
            regions: 2,
            ppr: 1,
            requests: 6,
            warmup: 2,
            fuse_batch: 2,
            rs_shards: 1,
            n_gather: 64,
            ..RpsConfig::default()
        };
        let rep = serve_rps(&cfg).expect("sim rps run");
        assert!(rep.verified, "synthetic serving data must verify on both passes");
        assert_eq!(rep.p, 2);
        assert_eq!(rep.requests, 6);
        assert!(rep.rps_staged > 0.0 && rep.rps_zero_copy > 0.0);
    }
}
