//! The serving loop: batched tensor-parallel inference over the mini-MPI
//! with PJRT compute and a **fused** collective hot path.
//!
//! Every chunk of `fuse_batch` requests executes ONE fused schedule
//! ([`crate::collectives::FusedPlan`]): the chunk's allgathers are
//! round-merged and message-coalesced with each other and with the
//! consensus allreduce, so the coordinator pays one wire message where
//! sequential execution pays one per collective. The consensus probes are
//! pipelined one chunk behind (a probe depends on the projected output,
//! which depends on the same request's allgather), with a drain allreduce
//! after the final chunk so every request is still verified.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use crate::collectives::{self, Algorithm, FuseSpec, OpKind, Shape};
use crate::comm::{Comm, CommWorld, Timing};
use crate::coordinator::metrics::{RequestTiming, ServeMetrics};
use crate::coordinator::params::{max_abs_diff, ModelParams};
use crate::error::{Error, Result};
use crate::runtime::{Engine, Manifest};
use crate::topology::Topology;
use crate::trace::TraceSummary;
use crate::transport::{Backend, DType, PoolGate, ProcConfig, ProcJob, ProcPool};

/// Configuration of one serving run.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Directory holding `manifest.json` + `*.hlo.txt` (from `make artifacts`).
    pub artifact_dir: PathBuf,
    /// Allgather algorithm on the activation path.
    pub algo: Algorithm,
    /// Number of locality regions the TP workers span (must divide tp).
    pub regions: usize,
    /// Measured batched requests.
    pub requests: usize,
    /// Unmeasured warmup requests.
    pub warmup: usize,
    /// Verify outputs against the in-Rust reference forward.
    pub check: bool,
    /// Use the fused `gathered_matmul` artifact: the final projection
    /// consumes the allgather's rank-order buffer directly, skipping the
    /// `h_full` assembly pass (perf pass, L2/L1 fusion).
    pub fused: bool,
    /// Cross-worker output consensus: a planned allreduce (two f32 probes
    /// per request, riding the fused schedule one chunk behind) sums an
    /// output fingerprint across workers; any worker whose projection
    /// diverged breaks the `p·x` identity and fails verification. Skipped
    /// when the topology admits no allreduce plan (unsupported shape /
    /// topology preconditions); genuine plan failures propagate.
    pub consensus: bool,
    /// Request micro-batch size `K`: the serving loop processes requests
    /// in chunks of `K`, executing the chunk's `K` allgathers (plus the
    /// consensus allreduce) as one fused, coalesced schedule. `1` fuses
    /// only the allgather with the consensus allreduce.
    pub fuse_batch: usize,
    /// Backend the fused collective hot path executes on. [`Backend::Sim`]
    /// runs the fused schedule over in-process thread mailboxes;
    /// [`Backend::Proc`] spawns a persistent [`ProcPool`] (one OS process
    /// per TP worker) before the serving threads start, ships the fused
    /// schedule to it once, and every chunk's collective crosses real
    /// process boundaries over shm rings and Unix sockets via a
    /// [`PoolGate`] exchange.
    pub collective_backend: Backend,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            artifact_dir: Manifest::default_dir(),
            // The model-tuned dispatcher plans whatever the cost model says
            // is cheapest for the worker topology and activation shape.
            algo: Algorithm::ModelTuned,
            regions: 2,
            requests: 16,
            warmup: 2,
            check: true,
            fused: false,
            consensus: true,
            fuse_batch: 1,
            collective_backend: Backend::Sim,
        }
    }
}

/// Outcome of a serving run.
#[derive(Debug)]
pub struct ServeReport {
    pub metrics: ServeMetrics,
    /// True if every checked output matched the reference within tolerance.
    pub verified: bool,
    /// Max |err| observed against the reference.
    pub max_err: f32,
    /// Traffic accounting over the whole run.
    pub trace: TraceSummary,
    /// First few values of the last response (for quickstart printing).
    pub output_sample: Vec<f32>,
    /// Model dimensions served.
    pub tp: usize,
    pub params: usize,
}

/// Run the TP serving loop. One thread per TP worker; worker 0 doubles as
/// the leader (generates/broadcasts batches, records metrics).
pub fn serve(cfg: &ServeConfig) -> Result<ServeReport> {
    // Validate artifacts & dims on the main thread for clean errors.
    let manifest = Manifest::load(&cfg.artifact_dir)?;
    let dims = manifest.model;
    let tp = dims.tp;
    if cfg.regions == 0 || tp % cfg.regions != 0 {
        return Err(Error::Coordinator(format!(
            "regions={} must divide tp={tp}",
            cfg.regions
        )));
    }
    let topo = Topology::regions(cfg.regions, tp / cfg.regions);
    let total_reqs = cfg.warmup + cfg.requests;
    let algo = cfg.algo;
    let check = cfg.check;
    let dir = cfg.artifact_dir.clone();

    let fused = cfg.fused;
    let consensus = cfg.consensus;
    let fuse_batch = cfg.fuse_batch.max(1);

    // With the proc collective backend the pool and its fused schedule are
    // fixed BEFORE the worker threads exist: replicate the serving loop's
    // constituent decision comm-free, spawn the pool (workers handshake
    // once), ship the fused schedule to it once, and hand every worker
    // thread a gate onto the shared pool. Each chunk then crosses real
    // OS-process boundaries while planning costs nothing per request.
    let (gate, gate_consensus) = if cfg.collective_backend == Backend::Proc {
        let machine = crate::model::MachineParams::lassen();
        let n_gather = dims.batch * dims.hidden_shard();
        let (specs, wc) =
            serving_pool_specs(&topo, cfg.algo, n_gather, fuse_batch, cfg.consensus, &machine)?;
        let mut pool =
            ProcPool::spawn(cfg.regions, tp / cfg.regions, machine.name, &ProcConfig::default())?;
        let sid = pool.load(&ProcJob::Fused { specs, dtype: DType::F32 })?;
        (Some(Arc::new(PoolGate::new(pool, sid))), wc)
    } else {
        (None, false)
    };

    let start = Instant::now();
    let run = CommWorld::run(&topo, Timing::Wallclock, move |c| -> Result<WorkerOut> {
        worker_loop(
            c,
            &dir,
            algo,
            total_reqs,
            cfg.warmup,
            check,
            fused,
            consensus,
            fuse_batch,
            gate.as_deref(),
            gate_consensus,
        )
    });
    let window = start.elapsed().as_secs_f64();

    // Worker 0 carries the report; surface any worker's error.
    let mut out0 = None;
    for (rank, res) in run.results.into_iter().enumerate() {
        match res {
            Ok(o) => {
                if rank == 0 {
                    out0 = Some(o);
                }
            }
            Err(e) => {
                return Err(Error::Coordinator(format!("worker {rank}: {e}")));
            }
        }
    }
    let out0 = out0.expect("worker 0 always present");
    Ok(ServeReport {
        metrics: ServeMetrics::new(out0.timings, window),
        verified: out0.verified && out0.consensus_ok,
        max_err: out0.max_err,
        trace: run.trace,
        output_sample: out0.sample,
        tp,
        params: dims.params,
    })
}

struct WorkerOut {
    timings: Vec<RequestTiming>,
    verified: bool,
    /// True unless the consensus allreduce caught divergent outputs.
    consensus_ok: bool,
    max_err: f32,
    sample: Vec<f32>,
}

/// Compare a summed fingerprint against `p × mine` (float reassociation
/// slack allowed); clears `ok` on divergence.
fn check_probes(sum: &[f32], mine: &[f32], pf: f32, ok: &mut bool) {
    for (got, m) in sum.iter().zip(mine) {
        if (got - pf * m).abs() > 1e-3 * (1.0 + (pf * m).abs()) {
            *ok = false;
        }
    }
}

/// Plan the chunk's fused schedule: `k` allgathers (one per request of the
/// chunk) plus, when consensus is requested and the topology admits it,
/// one `2k`-probe consensus allreduce. Returns the plan and whether the
/// consensus constituent is on board.
///
/// Only failures of the consensus constituent *itself* (its schedule
/// builder rejecting the shape / topology) downgrade to a consensus-free
/// plan — genuine plan failures propagate. (The old serving loop
/// swallowed every consensus planning error with `.ok()`.)
fn plan_serving_fused(
    c: &Comm,
    algo: Algorithm,
    n_gather: usize,
    k: usize,
    consensus: bool,
) -> Result<(collectives::FusedPlan<f32>, bool)> {
    let mut specs: Vec<FuseSpec> =
        (0..k).map(|_| FuseSpec::new(OpKind::Allgather, algo.name(), n_gather)).collect();
    if consensus {
        specs.push(FuseSpec::new(OpKind::Allreduce, "loc-aware", 2 * k));
        match collectives::plan_fused::<f32>(c, &specs) {
            Ok(p) => return Ok((p, true)),
            Err(e) => {
                specs.pop();
                // Downgrade to consensus-free serving ONLY when the
                // consensus constituent itself rejects this topology /
                // shape (its builder fails, e.g. non-power-of-two worker
                // groups). Every other failure — an allgather problem, a
                // fusion-consistency failure — propagates. (The old loop
                // swallowed all of these with `.ok()`.)
                let view = collectives::schedule::WorldView::from_comm(c);
                let probe = collectives::schedule::build_allreduce(
                    "loc-aware",
                    &view,
                    c.rank(),
                    2 * k,
                    std::mem::size_of::<f32>(),
                );
                if probe.is_ok() {
                    return Err(e);
                }
            }
        }
    }
    Ok((collectives::plan_fused::<f32>(c, &specs)?, false))
}

/// Comm-free replica of [`plan_serving_fused`]'s constituent decision for
/// the proc backend: the pool's fused job must be fixed before any worker
/// thread exists, so the same try-with-consensus / probe-the-builder
/// downgrade logic runs against a [`WorldView`] of the topology instead
/// of a live communicator. Returns the fused specs and whether the
/// consensus allreduce is on board.
///
/// [`WorldView`]: collectives::schedule::WorldView
fn serving_pool_specs(
    topo: &Topology,
    algo: Algorithm,
    n_gather: usize,
    k: usize,
    consensus: bool,
    machine: &crate::model::MachineParams,
) -> Result<(Vec<FuseSpec>, bool)> {
    use crate::collectives::{fuse, schedule};
    let esz = std::mem::size_of::<f32>();
    let view = schedule::WorldView::world(topo);
    let mut specs: Vec<FuseSpec> =
        (0..k).map(|_| FuseSpec::new(OpKind::Allgather, algo.name(), n_gather)).collect();
    if consensus {
        specs.push(FuseSpec::new(OpKind::Allreduce, "loc-aware", 2 * k));
        match fuse::fuse_world(&specs, &view, esz, machine) {
            Ok(_) => return Ok((specs, true)),
            Err(e) => {
                specs.pop();
                // Same downgrade contract as plan_serving_fused: only the
                // consensus constituent's own builder rejecting this
                // topology / shape drops it from the plan.
                let probe = schedule::build_allreduce("loc-aware", &view, 0, 2 * k, esz);
                if probe.is_ok() {
                    return Err(e);
                }
            }
        }
    }
    fuse::fuse_world(&specs, &view, esz, machine)?;
    Ok((specs, false))
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    c: &mut Comm,
    artifact_dir: &std::path::Path,
    algo: Algorithm,
    total_reqs: usize,
    warmup: usize,
    check: bool,
    fused: bool,
    consensus: bool,
    fuse_batch: usize,
    gate: Option<&PoolGate>,
    gate_consensus: bool,
) -> Result<WorkerOut> {
    // Each worker owns a private PJRT engine (the client is !Send).
    let engine = Engine::load(artifact_dir)?;
    let dims = engine.manifest.model;
    let (b, hs, h) = (dims.batch, dims.hidden_shard(), dims.d_hidden);
    let params = ModelParams::generate(dims, 0.0);
    let w1s = params.w1_shard(c.rank());
    let partial = engine.executable("partial_fwd")?;
    let final_ = engine.executable("final_fwd")?;
    let fused_final = if fused {
        Some(engine.executable("fused_final")?)
    } else {
        None
    };

    // The fused plan is built ONCE per worker: every request moves the
    // same (batch, hidden_shard) activation shape, so the serving loop is
    // the persistent-plan use case — all setup (schedule fusion, message
    // coalescing, tags, scratch) amortizes across all requests and the
    // hot path executes one coalesced schedule per chunk into reused
    // caller-owned buffers. On the proc backend the schedule already
    // lives in the worker pool (loaded once before these threads
    // started), so nothing is planned here at all.
    let k = fuse_batch.max(1);
    let (mut fplan, with_consensus) = match gate {
        Some(_) => (None, gate_consensus),
        None => {
            let (plan, wc) = plan_serving_fused(c, algo, b * hs, k, consensus)?;
            (Some(plan), wc)
        }
    };

    // The drain allreduce verifies the FINAL chunk's probes after the
    // loop (the fused consensus runs one chunk behind).
    let mut drain_plan = if with_consensus {
        Some(collectives::plan_allreduce::<f32>("loc-aware", c, Shape::elems(2 * k))?)
    } else {
        None
    };

    let mut gathered: Vec<Vec<f32>> = (0..k).map(|_| vec![0f32; b * hs * c.size()]).collect();
    let mut probe_sum = vec![0f32; 2 * k];
    // This worker's own probes of the previous chunk (what the in-flight
    // consensus sum is verified against).
    let mut probes_prev: Option<Vec<f32>> = None;

    let mut timings = Vec::with_capacity(total_reqs.saturating_sub(warmup));
    let mut verified = true;
    let mut consensus_ok = true;
    let mut max_err = 0f32;
    let mut sample = Vec::new();
    let pf = c.size() as f32;

    // Chunked request loop. The final chunk is padded with zero batches so
    // every fused execution is a full collective; padded requests are
    // computed but never recorded or checked.
    let chunks = total_reqs.div_ceil(k);
    for chunk in 0..chunks {
        let t_chunk = Instant::now();
        let mut h_parts: Vec<Vec<f32>> = Vec::with_capacity(k);
        let mut t_partials = vec![0f64; k];
        for (j, t_partial) in t_partials.iter_mut().enumerate() {
            let req = chunk * k + j;
            // Leader generates the batch and broadcasts it (request ingress).
            let x = if c.rank() == 0 {
                let seed = if req < total_reqs { req as f32 + 1.0 } else { 0.0 };
                Some(params.example_batch(seed))
            } else {
                None
            };
            let x = collectives::primitives::bcast(c, x, 0)?;

            // Phase 1: PJRT partial forward (Pallas kernel inside).
            let t0 = Instant::now();
            let h_part = partial.run_f32(&[&x, &w1s])?;
            *t_partial = t0.elapsed().as_secs_f64();
            h_parts.push(h_part);
        }

        // Phase 2: ONE fused execution — the chunk's k allgathers plus the
        // previous chunk's consensus sum, coalesced into shared wire
        // messages. The first chunk sums zero probes (nothing to verify).
        let probes_in: Vec<f32> = probes_prev.clone().unwrap_or_else(|| vec![0f32; 2 * k]);
        let t1 = Instant::now();
        if let Some(g) = gate {
            // Proc backend: serialize the chunk's composite fused input
            // (k allgather shards, then the 2k consensus probes — the
            // pool job's constituent order), exchange it through the
            // shared pool, and split the composite output back out.
            let n_in = k * b * hs + if with_consensus { 2 * k } else { 0 };
            let mut inbytes = Vec::with_capacity(n_in * 4);
            for hp in &h_parts {
                for v in hp {
                    inbytes.extend_from_slice(&v.to_ne_bytes());
                }
            }
            if with_consensus {
                for v in &probes_in {
                    inbytes.extend_from_slice(&v.to_ne_bytes());
                }
            }
            let mut outbytes = Vec::new();
            g.exchange(c.rank(), &inbytes, &mut outbytes)?;
            let gather_bytes = b * hs * c.size() * 4;
            for (j, gj) in gathered.iter_mut().enumerate() {
                let blk = &outbytes[j * gather_bytes..(j + 1) * gather_bytes];
                for (dst, chunk) in gj.iter_mut().zip(blk.chunks_exact(4)) {
                    *dst = f32::from_ne_bytes(chunk.try_into().expect("4-byte chunk"));
                }
            }
            if with_consensus {
                let probes = &outbytes[k * gather_bytes..];
                for (dst, chunk) in probe_sum.iter_mut().zip(probes.chunks_exact(4)) {
                    *dst = f32::from_ne_bytes(chunk.try_into().expect("4-byte chunk"));
                }
            }
        } else {
            let mut in_refs: Vec<&[f32]> = h_parts.iter().map(|v| v.as_slice()).collect();
            let mut out_refs: Vec<&mut [f32]> =
                gathered.iter_mut().map(|v| v.as_mut_slice()).collect();
            if with_consensus {
                in_refs.push(&probes_in);
                out_refs.push(&mut probe_sum);
            }
            fplan.as_mut().expect("sim path planned above").execute(&in_refs, &mut out_refs)?;
        }
        let t_allgather = t1.elapsed().as_secs_f64();

        // Verify the in-flight consensus sum against last chunk's probes.
        if with_consensus {
            if let Some(prev) = probes_prev.take() {
                check_probes(&probe_sum, &prev, pf, &mut consensus_ok);
            }
        }

        // Phase 3: final projections, one per request of the chunk.
        let mut probes_now = vec![0f32; 2 * k];
        let mut t_finals = vec![0f64; k];
        for j in 0..k {
            let req = chunk * k + j;
            let t2 = Instant::now();
            let y = if let Some(ff) = &fused_final {
                ff.run_f32(&[&gathered[j], &params.w2])?
            } else {
                let mut h_full = vec![0f32; b * h];
                for i in 0..c.size() {
                    let blk = &gathered[j][i * b * hs..(i + 1) * b * hs];
                    for row in 0..b {
                        let dst = row * h + i * hs;
                        h_full[dst..dst + hs].copy_from_slice(&blk[row * hs..(row + 1) * hs]);
                    }
                }
                final_.run_f32(&[&h_full, &params.w2])?
            };
            t_finals[j] = t2.elapsed().as_secs_f64();
            probes_now[2 * j] = y[0];
            probes_now[2 * j + 1] = y[y.len() - 1];

            if c.rank() == 0 && req < total_reqs {
                if check {
                    let xr = params.example_batch(req as f32 + 1.0);
                    let want = params.reference_forward(&xr);
                    let err = max_abs_diff(&y, &want);
                    max_err = max_err.max(err);
                    if err > 1e-3 {
                        verified = false;
                    }
                }
                if req + 1 == total_reqs {
                    sample = y.iter().take(8).copied().collect();
                }
            }
        }
        if with_consensus {
            probes_prev = Some(probes_now);
        }

        if c.rank() == 0 {
            let chunk_total = t_chunk.elapsed().as_secs_f64();
            for j in 0..k {
                let req = chunk * k + j;
                if req >= warmup && req < total_reqs {
                    timings.push(RequestTiming {
                        partial: t_partials[j],
                        allgather: t_allgather / k as f64,
                        final_: t_finals[j],
                        total: chunk_total / k as f64,
                    });
                }
            }
        }
    }

    // Drain: the final chunk's probes have not been summed yet.
    if let (Some(dp), Some(prev)) = (drain_plan.as_mut(), probes_prev.take()) {
        dp.execute(&prev, &mut probe_sum)?;
        check_probes(&probe_sum, &prev, pf, &mut consensus_ok);
    }

    Ok(WorkerOut { timings, verified, consensus_ok, max_err, sample })
}

// Integration coverage (requires artifacts): rust/tests/coordinator_integration.rs
