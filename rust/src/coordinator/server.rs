//! The serving loop: batched tensor-parallel inference over the mini-MPI
//! with PJRT compute and a selectable allgather algorithm.

use std::path::PathBuf;
use std::time::Instant;

use crate::collectives::{self, Algorithm, Shape};
use crate::comm::{Comm, CommWorld, Timing};
use crate::coordinator::metrics::{RequestTiming, ServeMetrics};
use crate::coordinator::params::{max_abs_diff, ModelParams};
use crate::error::{Error, Result};
use crate::runtime::{Engine, Manifest};
use crate::topology::Topology;
use crate::trace::TraceSummary;

/// Configuration of one serving run.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Directory holding `manifest.json` + `*.hlo.txt` (from `make artifacts`).
    pub artifact_dir: PathBuf,
    /// Allgather algorithm on the activation path.
    pub algo: Algorithm,
    /// Number of locality regions the TP workers span (must divide tp).
    pub regions: usize,
    /// Measured batched requests.
    pub requests: usize,
    /// Unmeasured warmup requests.
    pub warmup: usize,
    /// Verify outputs against the in-Rust reference forward.
    pub check: bool,
    /// Use the fused `gathered_matmul` artifact: the final projection
    /// consumes the allgather's rank-order buffer directly, skipping the
    /// `h_full` assembly pass (perf pass, L2/L1 fusion).
    pub fused: bool,
    /// Cross-worker output consensus: a persistent planned allreduce (two
    /// f32 probes per request) sums an output fingerprint across workers;
    /// any worker whose projection diverged breaks the `p·x` identity and
    /// fails verification. Skipped when the topology admits no allreduce
    /// plan (non-power-of-two, unaligned worker counts).
    pub consensus: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            artifact_dir: Manifest::default_dir(),
            // The model-tuned dispatcher plans whatever the cost model says
            // is cheapest for the worker topology and activation shape.
            algo: Algorithm::ModelTuned,
            regions: 2,
            requests: 16,
            warmup: 2,
            check: true,
            fused: false,
            consensus: true,
        }
    }
}

/// Outcome of a serving run.
#[derive(Debug)]
pub struct ServeReport {
    pub metrics: ServeMetrics,
    /// True if every checked output matched the reference within tolerance.
    pub verified: bool,
    /// Max |err| observed against the reference.
    pub max_err: f32,
    /// Traffic accounting over the whole run.
    pub trace: TraceSummary,
    /// First few values of the last response (for quickstart printing).
    pub output_sample: Vec<f32>,
    /// Model dimensions served.
    pub tp: usize,
    pub params: usize,
}

/// Run the TP serving loop. One thread per TP worker; worker 0 doubles as
/// the leader (generates/broadcasts batches, records metrics).
pub fn serve(cfg: &ServeConfig) -> Result<ServeReport> {
    // Validate artifacts & dims on the main thread for clean errors.
    let manifest = Manifest::load(&cfg.artifact_dir)?;
    let dims = manifest.model;
    let tp = dims.tp;
    if cfg.regions == 0 || tp % cfg.regions != 0 {
        return Err(Error::Coordinator(format!(
            "regions={} must divide tp={tp}",
            cfg.regions
        )));
    }
    let topo = Topology::regions(cfg.regions, tp / cfg.regions);
    let total_reqs = cfg.warmup + cfg.requests;
    let algo = cfg.algo;
    let check = cfg.check;
    let dir = cfg.artifact_dir.clone();

    let start = Instant::now();
    let fused = cfg.fused;
    let consensus = cfg.consensus;
    let run = CommWorld::run(&topo, Timing::Wallclock, move |c| -> Result<WorkerOut> {
        worker_loop(c, &dir, algo, total_reqs, cfg.warmup, check, fused, consensus)
    });
    let window = start.elapsed().as_secs_f64();

    // Worker 0 carries the report; surface any worker's error.
    let mut out0 = None;
    for (rank, res) in run.results.into_iter().enumerate() {
        match res {
            Ok(o) => {
                if rank == 0 {
                    out0 = Some(o);
                }
            }
            Err(e) => {
                return Err(Error::Coordinator(format!("worker {rank}: {e}")));
            }
        }
    }
    let out0 = out0.expect("worker 0 always present");
    Ok(ServeReport {
        metrics: ServeMetrics::new(out0.timings, window),
        verified: out0.verified && out0.consensus_ok,
        max_err: out0.max_err,
        trace: run.trace,
        output_sample: out0.sample,
        tp,
        params: dims.params,
    })
}

struct WorkerOut {
    timings: Vec<RequestTiming>,
    verified: bool,
    /// True unless the consensus allreduce caught divergent outputs.
    consensus_ok: bool,
    max_err: f32,
    sample: Vec<f32>,
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    c: &mut Comm,
    artifact_dir: &std::path::Path,
    algo: Algorithm,
    total_reqs: usize,
    warmup: usize,
    check: bool,
    fused: bool,
    consensus: bool,
) -> Result<WorkerOut> {
    // Each worker owns a private PJRT engine (the client is !Send).
    let engine = Engine::load(artifact_dir)?;
    let dims = engine.manifest.model;
    let (b, hs, h) = (dims.batch, dims.hidden_shard(), dims.d_hidden);
    let params = ModelParams::generate(dims, 0.0);
    let w1s = params.w1_shard(c.rank());
    let partial = engine.executable("partial_fwd")?;
    let final_ = engine.executable("final_fwd")?;
    let fused_final = if fused {
        Some(engine.executable("fused_final")?)
    } else {
        None
    };

    // The allgather is planned ONCE per worker: every request moves the
    // same (batch, hidden_shard) activation shape, so the serving loop is
    // the persistent-plan use case — setup (groups, sub-communicators,
    // schedules, tags, scratch) amortizes across all requests and the hot
    // path executes into a reused caller-owned buffer.
    let mut ag_plan = collectives::plan_allgather::<f32>(algo, c, Shape::elems(b * hs))?;
    let mut gathered = vec![0f32; b * hs * c.size()];

    // The consensus allreduce is also planned ONCE: two f32 probes per
    // request. Topologies without a valid allreduce plan (non-power-of-two
    // unaligned worker counts) skip consensus rather than fail serving —
    // every worker sees the same topology, so the skip is collective.
    let mut sum_plan = if consensus {
        collectives::plan_allreduce::<f32>("loc-aware", c, Shape::elems(2)).ok()
    } else {
        None
    };
    let mut probe_sum = [0f32; 2];

    let mut timings = Vec::with_capacity(total_reqs.saturating_sub(warmup));
    let mut verified = true;
    let mut consensus_ok = true;
    let mut max_err = 0f32;
    let mut sample = Vec::new();

    for req in 0..total_reqs {
        let t_start = Instant::now();
        // Leader generates the batch and broadcasts it (request ingress).
        let x = if c.rank() == 0 {
            Some(params.example_batch(req as f32 + 1.0))
        } else {
            None
        };
        let x = collectives::primitives::bcast(c, x, 0)?;

        // Phase 1: PJRT partial forward (Pallas kernel inside).
        let t0 = Instant::now();
        let h_part = partial.run_f32(&[&x, &w1s])?;
        let t_partial = t0.elapsed().as_secs_f64();

        // Phase 2: the allgather under study — persistent plan, zero setup.
        let t1 = Instant::now();
        ag_plan.execute(&h_part, &mut gathered)?;
        let t_allgather = t1.elapsed().as_secs_f64();

        // Phase 3: the final projection. Fused path: the gathered buffer
        // feeds the gathered_matmul kernel directly; unfused path:
        // assemble (batch, d_hidden) row-major first.
        let t2 = Instant::now();
        let y = if let Some(ff) = fused_final {
            ff.run_f32(&[&gathered, &params.w2])?
        } else {
            let mut h_full = vec![0f32; b * h];
            for i in 0..c.size() {
                let blk = &gathered[i * b * hs..(i + 1) * b * hs];
                for row in 0..b {
                    let dst = row * h + i * hs;
                    h_full[dst..dst + hs].copy_from_slice(&blk[row * hs..(row + 1) * hs]);
                }
            }
            final_.run_f32(&[&h_full, &params.w2])?
        };
        let t_final = t2.elapsed().as_secs_f64();

        // Cross-worker consensus: every worker computed the full `y`, so
        // the summed fingerprint must equal p × our own (within float
        // reassociation slack). Collective — all workers execute it.
        if let Some(sp) = sum_plan.as_mut() {
            let probe = [y[0], y[y.len() - 1]];
            sp.execute(&probe, &mut probe_sum)?;
            let pf = c.size() as f32;
            for (got, mine) in probe_sum.iter().zip(probe) {
                if (got - pf * mine).abs() > 1e-3 * (1.0 + (pf * mine).abs()) {
                    consensus_ok = false;
                }
            }
        }

        if c.rank() == 0 {
            if req >= warmup {
                timings.push(RequestTiming {
                    partial: t_partial,
                    allgather: t_allgather,
                    final_: t_final,
                    total: t_start.elapsed().as_secs_f64(),
                });
            }
            if check {
                let want = params.reference_forward(&x);
                let err = max_abs_diff(&y, &want);
                max_err = max_err.max(err);
                if err > 1e-3 {
                    verified = false;
                }
            }
            if req + 1 == total_reqs {
                sample = y.iter().take(8).copied().collect();
            }
        }
    }
    Ok(WorkerOut { timings, verified, consensus_ok, max_err, sample })
}

// Integration coverage (requires artifacts): rust/tests/coordinator_integration.rs
