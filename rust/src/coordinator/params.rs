//! Deterministic model parameters + in-Rust reference forward pass.
//!
//! Mirrors `python/compile/model.py` exactly: the parameters are
//! trigonometric lattices (no RNG in the build path), so Rust can generate
//! bit-comparable inputs and validate the PJRT pipeline end-to-end without
//! shipping weights through files.

use crate::runtime::ModelDims;

/// The TP-MLP parameters, generated to match `model.init_params`.
#[derive(Debug, Clone)]
pub struct ModelParams {
    pub dims: ModelDims,
    /// `W1`: (d_model, d_hidden), row-major.
    pub w1: Vec<f32>,
    /// `W2`: (d_hidden, d_out), row-major.
    pub w2: Vec<f32>,
}

impl ModelParams {
    /// Generate parameters for `dims` with the given seed (must match the
    /// Python default seed 0 for artifact-aligned runs).
    pub fn generate(dims: ModelDims, seed: f32) -> ModelParams {
        let (d, h, o) = (dims.d_model, dims.d_hidden, dims.d_out);
        let mut w1 = vec![0f32; d * h];
        let inv_sqrt_d = 1.0 / (d as f32).sqrt();
        for i in 0..d {
            for j in 0..h {
                w1[i * h + j] =
                    0.05 * (0.7 * i as f32 + 1.3 * j as f32 + seed).sin() * inv_sqrt_d;
            }
        }
        let mut w2 = vec![0f32; h * o];
        let inv_sqrt_h = 1.0 / (h as f32).sqrt();
        for k in 0..h {
            for l in 0..o {
                w2[k * o + l] =
                    0.05 * (0.9 * k as f32 - 0.4 * l as f32 + seed).cos() * inv_sqrt_h;
            }
        }
        ModelParams { dims, w1, w2 }
    }

    /// Column shard `i` of `W1`: (d_model, hidden_shard), row-major.
    pub fn w1_shard(&self, i: usize) -> Vec<f32> {
        let (d, h) = (self.dims.d_model, self.dims.d_hidden);
        let hs = self.dims.hidden_shard();
        assert!(i < self.dims.tp);
        let mut out = Vec::with_capacity(d * hs);
        for row in 0..d {
            let base = row * h + i * hs;
            out.extend_from_slice(&self.w1[base..base + hs]);
        }
        out
    }

    /// The deterministic example batch (matches `model.example_batch`).
    pub fn example_batch(&self, seed: f32) -> Vec<f32> {
        let (b, d) = (self.dims.batch, self.dims.d_model);
        let mut x = vec![0f32; b * d];
        for bb in 0..b {
            for dd in 0..d {
                x[bb * d + dd] = (0.3 * bb as f32 + 0.11 * dd as f32 + seed).sin();
            }
        }
        x
    }

    /// Reference forward pass: `gelu(x @ W1) @ W2` in plain Rust f32.
    pub fn reference_forward(&self, x: &[f32]) -> Vec<f32> {
        let (b, d, h, o) = (
            self.dims.batch,
            self.dims.d_model,
            self.dims.d_hidden,
            self.dims.d_out,
        );
        assert_eq!(x.len(), b * d);
        let mut hbuf = vec![0f32; b * h];
        matmul(x, &self.w1, &mut hbuf, b, d, h);
        for v in hbuf.iter_mut() {
            *v = gelu(*v);
        }
        let mut y = vec![0f32; b * o];
        matmul(&hbuf, &self.w2, &mut y, b, h, o);
        y
    }
}

/// tanh-approximated GeLU, matching `kernels/ref.py`.
pub fn gelu(x: f32) -> f32 {
    let c = (2.0f32 / std::f32::consts::PI).sqrt();
    0.5 * x * (1.0 + (c * (x + 0.044715 * x * x * x)).tanh())
}

/// Row-major `C[b,n] = A[b,m] @ B[m,n]`.
pub fn matmul(a: &[f32], b: &[f32], c: &mut [f32], bb: usize, m: usize, n: usize) {
    assert_eq!(a.len(), bb * m);
    assert_eq!(b.len(), m * n);
    assert_eq!(c.len(), bb * n);
    for i in 0..bb {
        let crow = &mut c[i * n..(i + 1) * n];
        crow.fill(0.0);
        for k in 0..m {
            let aik = a[i * m + k];
            if aik == 0.0 {
                continue;
            }
            let brow = &b[k * n..(k + 1) * n];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += aik * bv;
            }
        }
    }
}

/// Max |a-b| over two buffers (for end-to-end tolerance checks).
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ModelDims {
        ModelDims { batch: 2, d_model: 8, d_hidden: 16, d_out: 4, tp: 4, params: 0 }
    }

    #[test]
    fn shards_tile_w1() {
        let p = ModelParams::generate(dims(), 0.0);
        let hs = p.dims.hidden_shard();
        // reassemble from shards and compare
        let mut back = vec![0f32; p.dims.d_model * p.dims.d_hidden];
        for i in 0..p.dims.tp {
            let sh = p.w1_shard(i);
            for row in 0..p.dims.d_model {
                let dst = row * p.dims.d_hidden + i * hs;
                back[dst..dst + hs].copy_from_slice(&sh[row * hs..(row + 1) * hs]);
            }
        }
        assert_eq!(back, p.w1);
    }

    #[test]
    fn reference_forward_shapes_and_determinism() {
        let p = ModelParams::generate(dims(), 0.0);
        let x = p.example_batch(1.0);
        let y1 = p.reference_forward(&x);
        let y2 = p.reference_forward(&x);
        assert_eq!(y1.len(), 2 * 4);
        assert_eq!(y1, y2);
        assert!(y1.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn gelu_fixed_points() {
        assert_eq!(gelu(0.0), 0.0);
        assert!((gelu(3.0) - 3.0).abs() < 0.01); // ≈ identity for large x
        assert!(gelu(-3.0).abs() < 0.01); // ≈ 0 for very negative x
        assert!((gelu(1.0) - 0.8412).abs() < 1e-3);
    }

    #[test]
    fn matmul_small_known() {
        // [1 2; 3 4] @ [5 6; 7 8] = [19 22; 43 50]
        let a = [1., 2., 3., 4.];
        let b = [5., 6., 7., 8.];
        let mut c = [0f32; 4];
        matmul(&a, &b, &mut c, 2, 2, 2);
        assert_eq!(c, [19., 22., 43., 50.]);
    }

    #[test]
    fn sharded_forward_equals_reference() {
        // simulate the TP pipeline in pure rust: partial per shard, concat
        // along hidden, final matmul
        let p = ModelParams::generate(dims(), 0.0);
        let x = p.example_batch(1.0);
        let (b, d, h, o) = (2usize, 8usize, 16usize, 4usize);
        let hs = h / p.dims.tp;
        let mut h_full = vec![0f32; b * h];
        for i in 0..p.dims.tp {
            let sh = p.w1_shard(i);
            let mut part = vec![0f32; b * hs];
            matmul(&x, &sh, &mut part, b, d, hs);
            for v in part.iter_mut() {
                *v = gelu(*v);
            }
            for row in 0..b {
                let dst = row * h + i * hs;
                h_full[dst..dst + hs].copy_from_slice(&part[row * hs..(row + 1) * hs]);
            }
        }
        let mut y = vec![0f32; b * o];
        matmul(&h_full, &p.w2, &mut y, b, h, o);
        let want = p.reference_forward(&x);
        assert!(max_abs_diff(&y, &want) < 1e-5);
    }
}
