//! In-tree property-testing kit (offline substitute for `proptest`).
//!
//! The offline crate registry has no `proptest`, so this module provides
//! the subset the test suite needs: seeded case generation with a
//! deterministic RNG, a configurable case count, failure reporting that
//! prints the reproducing seed, and size-aware generators for the domain's
//! shapes (process counts, region splits, payload sizes).
//!
//! ```
//! use locag::testkit::{check, Config};
//! check(Config::default().cases(64).named("bounds"), |g| {
//!     let x = g.usize_in(1, 100);
//!     assert!(x >= 1 && x <= 100);
//! });
//! ```

use crate::util::rng::Rng;

/// Property-check configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Number of generated cases.
    pub cases: usize,
    /// Base seed; case `i` derives its own seed from it.
    pub seed: u64,
    /// Name printed on failure.
    pub name: &'static str,
}

impl Default for Config {
    fn default() -> Self {
        // LOCAG_PROPTEST_CASES / LOCAG_PROPTEST_SEED widen runs or replay
        // failures printed by the failure guard.
        let cases = std::env::var("LOCAG_PROPTEST_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(48);
        let seed = std::env::var("LOCAG_PROPTEST_SEED")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(0xC0FFEE);
        Config { cases, seed, name: "property" }
    }
}

impl Config {
    /// Override the case count.
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    /// Override the base seed.
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    /// Name the property for failure messages.
    pub fn named(mut self, n: &'static str) -> Self {
        self.name = n;
        self
    }
}

/// Per-case generator handle.
pub struct Gen {
    rng: Rng,
    /// The seed reproducing this exact case (pass as LOCAG_PROPTEST_SEED
    /// with LOCAG_PROPTEST_CASES=1).
    pub case_seed: u64,
}

impl Gen {
    /// Construct directly from a case seed (replay path).
    pub fn from_seed(case_seed: u64) -> Gen {
        Gen { rng: Rng::new(case_seed), case_seed }
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.gen_range_inclusive(lo, hi)
    }

    /// Uniform choice from a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choose(xs)
    }

    /// Biased boolean.
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.gen_bool(p)
    }

    /// Random u64.
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// A power of two in `[1, max]`.
    pub fn pow2_upto(&mut self, max: usize) -> usize {
        assert!(max >= 1);
        let top = crate::util::ilog2_floor(max);
        1usize << self.usize_in(0, top as usize)
    }

    /// A (regions, ranks-per-region) pair with `regions·ppr ≤ max_p` and
    /// ppr a power of two (the paper's measurement constraint, §5).
    pub fn region_shape(&mut self, max_p: usize) -> (usize, usize) {
        let ppr = self.pow2_upto(max_p.min(16));
        let regions = self.usize_in(1, (max_p / ppr).max(1));
        (regions, ppr)
    }

    /// Payload length (elements), log-uniform-ish up to `max`.
    pub fn payload_len(&mut self, max: usize) -> usize {
        let cap = self.pow2_upto(max.max(1));
        self.usize_in(1, cap)
    }
}

/// Prints the reproducing seed if the property panics.
struct FailureGuard {
    name: &'static str,
    case: usize,
    case_seed: u64,
}

impl Drop for FailureGuard {
    fn drop(&mut self) {
        if std::thread::panicking() {
            eprintln!(
                "property '{}' failed on case {} — reproduce with \
                 LOCAG_PROPTEST_SEED={} LOCAG_PROPTEST_CASES=1 (direct case seed {:#x})",
                self.name, self.case, self.case_seed, self.case_seed
            );
        }
    }
}

/// Run `prop` over `cfg.cases` generated cases. On panic the failing case's
/// seed is printed before the panic propagates.
pub fn check<F: FnMut(&mut Gen)>(cfg: Config, mut prop: F) {
    for i in 0..cfg.cases {
        // With CASES=1 the base seed IS the case seed, enabling replay.
        let case_seed = if cfg.cases == 1 { cfg.seed } else { cfg.seed ^ splitmix(i as u64) };
        let guard = FailureGuard { name: cfg.name, case: i, case_seed };
        let mut g = Gen::from_seed(case_seed);
        prop(&mut g);
        std::mem::forget(guard);
    }
}

fn splitmix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(Config::default().cases(16).named("tautology"), |g| {
            let x = g.usize_in(3, 9);
            assert!((3..=9).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "always fails")]
    fn failing_property_panics_through() {
        check(Config::default().cases(4).named("demo"), |_g| {
            panic!("always fails");
        });
    }

    #[test]
    fn generators_respect_bounds() {
        check(Config::default().cases(64), |g| {
            let p2 = g.pow2_upto(64);
            assert!(p2.is_power_of_two() && p2 <= 64);
            let (r, ppr) = g.region_shape(64);
            assert!(r * ppr <= 64);
            assert!(ppr.is_power_of_two());
            let len = g.payload_len(128);
            assert!((1..=128).contains(&len));
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Vec::new();
        check(Config::default().seed(7).cases(8), |g| a.push(g.u64()));
        let mut b = Vec::new();
        check(Config::default().seed(7).cases(8), |g| b.push(g.u64()));
        assert_eq!(a, b);
        assert_eq!(a.len(), 8);
    }

    #[test]
    fn single_case_uses_base_seed_directly() {
        let mut direct = Gen::from_seed(0xABCD);
        let want = direct.u64();
        let mut got = None;
        check(Config::default().seed(0xABCD).cases(1), |g| {
            assert_eq!(g.case_seed, 0xABCD);
            got = Some(g.u64());
        });
        assert_eq!(got, Some(want));
    }
}
