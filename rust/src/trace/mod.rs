//! Communication tracing: per-rank message/byte accounting split by
//! locality class and by local/non-local region membership.
//!
//! The paper's analysis (§2.1, §4) is phrased in terms of the **maximum
//! number of non-local messages and bytes communicated by any process** —
//! e.g. standard Bruck sends `log2(p)` non-local messages of `m−1` total
//! values from the worst rank, while the locality-aware variant sends
//! `⌈log_pℓ(r)⌉` non-local messages of `≈ b/pℓ` bytes. The trace recorder
//! captures exactly those quantities from real executions so tests can
//! assert them and the quickstart can print the paper's Example 2.1 table.

use crate::topology::Locality;

/// One recorded message (event tracing is opt-in; see
/// [`crate::comm::CommWorld::run_traced`]). Used by `locag pattern` to
/// reproduce the paper's step-by-step communication figures (Figs. 1, 4).
#[derive(Debug, Clone, PartialEq)]
pub struct MsgEvent {
    /// Sender world rank.
    pub src: usize,
    /// Destination world rank.
    pub dst: usize,
    /// Message tag (collectives use `base + step`, so sorting by tag
    /// groups events into algorithm steps).
    pub tag: u64,
    /// Payload bytes.
    pub bytes: usize,
    /// Locality class of the (src, dst) pair.
    pub class: Locality,
    /// True if src and dst share a region.
    pub region_local: bool,
    /// Virtual send time (0 under wall-clock timing).
    pub vtime: f64,
}

/// Render events grouped into steps, paper-Fig.-1 style. A "step" is a
/// tag group; groups are ordered by their earliest virtual send time so
/// the phases of multi-phase algorithms (local gather → non-local
/// exchange → local gather) appear in execution order.
pub fn render_steps(events: &[MsgEvent]) -> String {
    use std::collections::BTreeMap;
    // (tag) -> (min vtime, events)
    let mut groups: BTreeMap<u64, (f64, Vec<&MsgEvent>)> = BTreeMap::new();
    for e in events {
        let g = groups.entry(e.tag).or_insert((f64::MAX, Vec::new()));
        g.0 = g.0.min(e.vtime);
        g.1.push(e);
    }
    let mut ordered: Vec<(f64, u64, Vec<&MsgEvent>)> =
        groups.into_iter().map(|(t, (v, es))| (v, t, es)).collect();
    ordered.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
    let mut out = String::new();
    for (step, (_, _, mut es)) in ordered.into_iter().enumerate() {
        es.sort_by_key(|e| e.src);
        out.push_str(&format!("step {}:\n", step + 1));
        for e in es {
            out.push_str(&format!(
                "  P{:<3} -> P{:<3} {:>6} B  [{}{}]\n",
                e.src,
                e.dst,
                e.bytes,
                e.class.label(),
                if e.region_local { "" } else { ", NON-LOCAL" }
            ));
        }
    }
    out
}

/// Per-rank send-side accounting.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RankTrace {
    /// Messages sent, by locality class.
    pub msgs: [u64; 3],
    /// Bytes sent, by locality class.
    pub bytes: [u64; 3],
    /// Messages sent within the sender's region.
    pub local_msgs: u64,
    /// Bytes sent within the sender's region.
    pub local_bytes: u64,
    /// Messages sent across regions.
    pub nonlocal_msgs: u64,
    /// Bytes sent across regions.
    pub nonlocal_bytes: u64,
}

impl RankTrace {
    /// Record one sent message.
    pub fn record(&mut self, class: Locality, is_region_local: bool, bytes: usize) {
        let c = class as usize;
        self.msgs[c] += 1;
        self.bytes[c] += bytes as u64;
        if is_region_local {
            self.local_msgs += 1;
            self.local_bytes += bytes as u64;
        } else {
            self.nonlocal_msgs += 1;
            self.nonlocal_bytes += bytes as u64;
        }
    }

    /// Total messages sent.
    pub fn total_msgs(&self) -> u64 {
        self.msgs.iter().sum()
    }

    /// Total bytes sent.
    pub fn total_bytes(&self) -> u64 {
        self.bytes.iter().sum()
    }

    /// Merge another trace into this one.
    pub fn merge(&mut self, other: &RankTrace) {
        for i in 0..3 {
            self.msgs[i] += other.msgs[i];
            self.bytes[i] += other.bytes[i];
        }
        self.local_msgs += other.local_msgs;
        self.local_bytes += other.local_bytes;
        self.nonlocal_msgs += other.nonlocal_msgs;
        self.nonlocal_bytes += other.nonlocal_bytes;
    }

    /// Reset all counters to zero.
    pub fn clear(&mut self) {
        *self = RankTrace::default();
    }
}

/// Aggregated view over all ranks of a run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TraceSummary {
    pub per_rank: Vec<RankTrace>,
}

impl TraceSummary {
    /// Build from per-rank traces.
    pub fn new(per_rank: Vec<RankTrace>) -> TraceSummary {
        TraceSummary { per_rank }
    }

    /// The paper's headline quantity: max non-local messages sent by any rank.
    pub fn max_nonlocal_msgs(&self) -> u64 {
        self.per_rank.iter().map(|t| t.nonlocal_msgs).max().unwrap_or(0)
    }

    /// Max non-local bytes sent by any rank.
    pub fn max_nonlocal_bytes(&self) -> u64 {
        self.per_rank.iter().map(|t| t.nonlocal_bytes).max().unwrap_or(0)
    }

    /// Max local messages sent by any rank.
    pub fn max_local_msgs(&self) -> u64 {
        self.per_rank.iter().map(|t| t.local_msgs).max().unwrap_or(0)
    }

    /// Max total messages sent by any rank.
    pub fn max_total_msgs(&self) -> u64 {
        self.per_rank.iter().map(|t| t.total_msgs()).max().unwrap_or(0)
    }

    /// Sum of non-local messages over all ranks (network injection load).
    pub fn total_nonlocal_msgs(&self) -> u64 {
        self.per_rank.iter().map(|t| t.nonlocal_msgs).sum()
    }

    /// Sum of non-local bytes over all ranks.
    pub fn total_nonlocal_bytes(&self) -> u64 {
        self.per_rank.iter().map(|t| t.nonlocal_bytes).sum()
    }

    /// Sum of bytes over all ranks and classes.
    pub fn total_bytes(&self) -> u64 {
        self.per_rank.iter().map(|t| t.total_bytes()).sum()
    }

    /// Totals by locality class: (msgs, bytes).
    pub fn by_class(&self, class: Locality) -> (u64, u64) {
        let c = class as usize;
        let msgs = self.per_rank.iter().map(|t| t.msgs[c]).sum();
        let bytes = self.per_rank.iter().map(|t| t.bytes[c]).sum();
        (msgs, bytes)
    }

    /// Per-operation view of a trace accumulated over `ops` identical
    /// collective executions (plan-once/execute-many benchmark loops):
    /// every counter divided by `ops`. Panics in debug builds if any
    /// counter is not an exact multiple (i.e. the executions were not
    /// identical).
    pub fn per_op(&self, ops: u64) -> TraceSummary {
        assert!(ops > 0, "per_op(0)");
        let div = |x: u64| {
            debug_assert_eq!(x % ops, 0, "trace counter {x} not a multiple of {ops} ops");
            x / ops
        };
        TraceSummary {
            per_rank: self
                .per_rank
                .iter()
                .map(|t| RankTrace {
                    msgs: [div(t.msgs[0]), div(t.msgs[1]), div(t.msgs[2])],
                    bytes: [div(t.bytes[0]), div(t.bytes[1]), div(t.bytes[2])],
                    local_msgs: div(t.local_msgs),
                    local_bytes: div(t.local_bytes),
                    nonlocal_msgs: div(t.nonlocal_msgs),
                    nonlocal_bytes: div(t.nonlocal_bytes),
                })
                .collect(),
        }
    }

    /// Render a compact human-readable table.
    pub fn table(&self) -> String {
        let mut out = String::new();
        out.push_str("class          msgs        bytes\n");
        for class in Locality::ALL {
            let (m, b) = self.by_class(class);
            out.push_str(&format!("{:<13} {:>6} {:>12}\n", class.label(), m, b));
        }
        out.push_str(&format!(
            "max/rank: {} non-local msgs, {} non-local bytes, {} total msgs\n",
            self.max_nonlocal_msgs(),
            self.max_nonlocal_bytes(),
            self.max_total_msgs()
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(src: usize, dst: usize, tag: u64, vtime: f64, local: bool) -> MsgEvent {
        MsgEvent {
            src,
            dst,
            tag,
            bytes: 8,
            class: if local { Locality::IntraSocket } else { Locality::InterNode },
            region_local: local,
            vtime,
        }
    }

    #[test]
    fn render_steps_orders_by_time_then_groups_by_tag() {
        let events = vec![
            ev(1, 0, 100, 2.0, false), // later step
            ev(0, 1, 50, 1.0, true),   // earlier step
            ev(2, 3, 50, 1.5, true),
        ];
        let s = render_steps(&events);
        let step1 = s.find("step 1:").unwrap();
        let step2 = s.find("step 2:").unwrap();
        assert!(step1 < step2);
        // tag 50 (earlier vtime) renders as step 1 and contains both sends
        let first_block = &s[step1..step2];
        assert!(first_block.contains("P0   -> P1"));
        assert!(first_block.contains("P2   -> P3"));
        // non-local marked
        assert!(s.contains("NON-LOCAL"));
    }

    #[test]
    fn render_steps_empty() {
        assert_eq!(render_steps(&[]), "");
    }

    #[test]
    fn record_and_totals() {
        let mut t = RankTrace::default();
        t.record(Locality::IntraSocket, true, 100);
        t.record(Locality::InterNode, false, 50);
        t.record(Locality::InterNode, false, 25);
        assert_eq!(t.total_msgs(), 3);
        assert_eq!(t.total_bytes(), 175);
        assert_eq!(t.local_msgs, 1);
        assert_eq!(t.nonlocal_msgs, 2);
        assert_eq!(t.nonlocal_bytes, 75);
    }

    #[test]
    fn merge_adds() {
        let mut a = RankTrace::default();
        a.record(Locality::IntraSocket, true, 10);
        let mut b = RankTrace::default();
        b.record(Locality::InterNode, false, 20);
        a.merge(&b);
        assert_eq!(a.total_msgs(), 2);
        assert_eq!(a.nonlocal_bytes, 20);
    }

    #[test]
    fn summary_maxima() {
        let mut a = RankTrace::default();
        a.record(Locality::InterNode, false, 10);
        a.record(Locality::InterNode, false, 10);
        let mut b = RankTrace::default();
        b.record(Locality::IntraSocket, true, 99);
        let s = TraceSummary::new(vec![a, b]);
        assert_eq!(s.max_nonlocal_msgs(), 2);
        assert_eq!(s.max_nonlocal_bytes(), 20);
        assert_eq!(s.max_local_msgs(), 1);
        assert_eq!(s.total_nonlocal_msgs(), 2);
        assert_eq!(s.by_class(Locality::IntraSocket), (1, 99));
        assert!(s.table().contains("inter-node"));
    }

    #[test]
    fn per_op_divides_all_counters() {
        let mut a = RankTrace::default();
        for _ in 0..3 {
            a.record(Locality::InterNode, false, 10);
            a.record(Locality::IntraSocket, true, 4);
        }
        let s = TraceSummary::new(vec![a]).per_op(3);
        assert_eq!(s.per_rank[0].nonlocal_msgs, 1);
        assert_eq!(s.per_rank[0].nonlocal_bytes, 10);
        assert_eq!(s.per_rank[0].local_msgs, 1);
        assert_eq!(s.per_rank[0].local_bytes, 4);
        assert_eq!(s.max_total_msgs(), 2);
    }

    #[test]
    fn empty_summary_is_zero() {
        let s = TraceSummary::default();
        assert_eq!(s.max_nonlocal_msgs(), 0);
        assert_eq!(s.total_bytes(), 0);
    }
}
