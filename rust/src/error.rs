//! Crate-wide error type.
//!
//! The message-passing substrate and the collectives report failures through
//! [`Error`]; higher layers (CLI, coordinator) wrap it in `anyhow` for
//! context-rich reporting.

use thiserror::Error;

/// Errors produced by the locag library.
#[derive(Debug, Error)]
pub enum Error {
    /// A rank index was outside the communicator size.
    #[error("rank {rank} out of range for communicator of size {size}")]
    RankOutOfRange { rank: usize, size: usize },

    /// A collective was invoked with inconsistent buffer sizes across ranks.
    #[error("buffer size mismatch in collective: expected {expected}, got {got}")]
    SizeMismatch { expected: usize, got: usize },

    /// The peer rank terminated (its mailbox was dropped / poisoned).
    #[error("peer rank {rank} disconnected during {during}")]
    Disconnected { rank: usize, during: &'static str },

    /// A receive saw a payload whose byte length is not a multiple of the
    /// element size of the expected datatype.
    #[error("datatype mismatch: payload of {bytes} bytes is not a whole number of {elem_size}-byte elements")]
    DatatypeMismatch { bytes: usize, elem_size: usize },

    /// Topology construction was given inconsistent parameters.
    #[error("invalid topology: {0}")]
    InvalidTopology(String),

    /// An algorithm precondition was violated (e.g. non-power-of-two size for
    /// an algorithm that requires it).
    #[error("algorithm precondition violated: {0}")]
    Precondition(String),

    /// PJRT runtime failures (artifact missing, compile error, shape error).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// The coordinator rejected or failed a request.
    #[error("coordinator error: {0}")]
    Coordinator(String),

    /// I/O failures from the figure harness / artifact loading.
    #[error(transparent)]
    Io(#[from] std::io::Error),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_messages_render() {
        let e = Error::RankOutOfRange { rank: 9, size: 4 };
        assert!(e.to_string().contains("rank 9"));
        let e = Error::SizeMismatch { expected: 8, got: 4 };
        assert!(e.to_string().contains("expected 8"));
        let e = Error::Disconnected { rank: 3, during: "recv" };
        assert!(e.to_string().contains("recv"));
    }

    #[test]
    fn io_error_converts() {
        let ioe = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = ioe.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
