//! Crate-wide error type.
//!
//! The message-passing substrate and the collectives report failures through
//! [`Error`]. The offline build environment has no crates.io access, so the
//! `Display`/`Error` impls are written by hand instead of derived via
//! `thiserror`.

use std::fmt;

/// Errors produced by the locag library.
#[derive(Debug)]
pub enum Error {
    /// A rank index was outside the communicator size.
    RankOutOfRange { rank: usize, size: usize },

    /// A collective was invoked with inconsistent buffer sizes across ranks.
    SizeMismatch { expected: usize, got: usize },

    /// The peer rank terminated (its mailbox was dropped / poisoned).
    Disconnected { rank: usize, during: &'static str },

    /// A receive saw a payload whose byte length is not a multiple of the
    /// element size of the expected datatype.
    DatatypeMismatch { bytes: usize, elem_size: usize },

    /// Topology construction was given inconsistent parameters.
    InvalidTopology(String),

    /// An algorithm precondition was violated (e.g. non-power-of-two size for
    /// an algorithm that requires it).
    Precondition(String),

    /// A multi-process transport failure (worker death, socket EOF, shm-ring
    /// timeout). Carries the rank the failure is attributed to and the
    /// schedule round that was in flight (0 when it happened during setup
    /// or teardown rather than inside a round).
    Transport { rank: usize, round: usize, what: String },

    /// PJRT runtime failures (artifact missing, compile error, shape error).
    Runtime(String),

    /// The coordinator rejected or failed a request.
    Coordinator(String),

    /// I/O failures from the figure harness / artifact loading.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::RankOutOfRange { rank, size } => {
                write!(f, "rank {rank} out of range for communicator of size {size}")
            }
            Error::SizeMismatch { expected, got } => {
                write!(f, "buffer size mismatch in collective: expected {expected}, got {got}")
            }
            Error::Disconnected { rank, during } => {
                write!(f, "peer rank {rank} disconnected during {during}")
            }
            Error::DatatypeMismatch { bytes, elem_size } => write!(
                f,
                "datatype mismatch: payload of {bytes} bytes is not a whole number of \
                 {elem_size}-byte elements"
            ),
            Error::Transport { rank, round, what } => {
                write!(f, "transport failure at rank {rank} (round {round}): {what}")
            }
            Error::InvalidTopology(s) => write!(f, "invalid topology: {s}"),
            Error::Precondition(s) => write!(f, "algorithm precondition violated: {s}"),
            Error::Runtime(s) => write!(f, "runtime error: {s}"),
            Error::Coordinator(s) => write!(f, "coordinator error: {s}"),
            Error::Io(e) => e.fmt(f),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_messages_render() {
        let e = Error::RankOutOfRange { rank: 9, size: 4 };
        assert!(e.to_string().contains("rank 9"));
        let e = Error::SizeMismatch { expected: 8, got: 4 };
        assert!(e.to_string().contains("expected 8"));
        let e = Error::Disconnected { rank: 3, during: "recv" };
        assert!(e.to_string().contains("recv"));
        let e = Error::Transport { rank: 2, round: 5, what: "peer closed socket".into() };
        let s = e.to_string();
        assert!(s.contains("rank 2") && s.contains("round 5") && s.contains("socket"));
    }

    #[test]
    fn io_error_converts() {
        let ioe = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = ioe.into();
        assert!(matches!(e, Error::Io(_)));
        assert!(std::error::Error::source(&e).is_some());
    }
}
