//! Hand-rolled CLI (the offline registry has no `clap`).
//!
//! ```text
//! locag quickstart                      # paper Example 2.1 walkthrough
//! locag run --op alltoall --algo loc-aware --regions 16 --ppr 8
//! locag run --op reduce-scatter --algo loc-aware       # §4 inverse sibling
//! locag run --op allgatherv --counts 4,0,7,2 --regions 2 --ppr 2  # ragged
//! locag run --algo model-tuned          # cost-model-selected allgather
//! locag explain --algo loc-bruck --regions 4 --ppr 4   # schedule + costs
//! locag explain --fused --regions 2 --ppr 8            # fused serving plan
//! locag fuse --batch 4 --regions 2 --ppr 8             # coalescing table
//! locag bench --json results/BENCH_collectives.json    # perf trajectory
//! locag bench --compare results/BENCH_baseline.json    # perf-regression gate
//! locag bench --backend proc            # + measured multi-process wall times
//! locag fit --quick --out results/params_fitted.json   # measured α/β params
//! locag allgather --algo loc-bruck --regions 16 --ppr 8 [--machine lassen]
//! locag figure 9 [--out results/fig9.csv] [--max-p 1024] [--backend proc]
//! locag pingpong [--machine quartz]
//! locag e2e [--algo model-tuned] [--regions 2] [--requests 16] [--artifacts DIR]
//! locag e2e --measure-rps --fuse-batch 4   # staged vs zero-copy serving req/s
//! locag validate [--max-p 256]
//! ```

pub mod args;
pub mod commands;

pub use args::Args;

use crate::error::Result;

/// Entry point called by `main`.
pub fn run(argv: Vec<String>) -> Result<i32> {
    let mut args = Args::parse(argv);
    let cmd = match args.positional.first().cloned() {
        Some(c) => c,
        None => {
            print!("{}", usage());
            return Ok(2);
        }
    };
    args.positional.remove(0);
    match cmd.as_str() {
        "quickstart" => commands::quickstart(&args),
        "algos" => commands::algos(&args),
        "run" => commands::run_op(&args),
        "allgather" => commands::allgather(&args),
        "explain" => commands::explain(&args),
        "fuse" => commands::fuse_cmd(&args),
        "bench" => commands::bench(&args),
        "figure" => commands::figure(&args),
        "pingpong" => commands::pingpong(&args),
        "fit" => commands::fit(&args),
        "pattern" => commands::pattern(&args),
        // Hidden: re-exec entry for proc-backend worker processes.
        "__worker" => Ok(crate::transport::worker_main(&args)),
        "e2e" => commands::e2e(&args),
        "validate" => commands::validate(&args),
        "help" | "--help" | "-h" => {
            print!("{}", usage());
            Ok(0)
        }
        other => {
            eprintln!("unknown command '{other}'\n");
            print!("{}", usage());
            Ok(2)
        }
    }
}

/// The top-level help text.
pub fn usage() -> String {
    "\
locag — locality-aware Bruck allgather (EuroMPI/USA'22 reproduction)

USAGE: locag <command> [options]

COMMANDS
  quickstart   Walk through paper Example 2.1 (16 ranks, 4 regions):
               per-algorithm traffic tables and modeled times.
  algos        List the algorithm registries of all six operations
               (allgather, allreduce, alltoall, reduce-scatter,
               allgatherv, reduce-scatter-v; name + one-line summary).
  run          Run any planned collective and report time/traffic.
               --op OP           allgather | allreduce | alltoall |
                                 reduce-scatter | allgatherv |
                                 reduce-scatter-v
               --algo NAME       (defaults: loc-bruck / loc-aware)
               --regions N       (default 16)
               --ppr N           ranks per region (default 8)
               --values N        values per rank (default 2)
               --counts C0,C1,.. per-rank counts for the ragged ops
                                 (allgatherv / reduce-scatter-v; must list
                                 exactly regions*ppr counts, zeros allowed;
                                 default: --values on every rank)
               --machine NAME    lassen | quartz | a locag-params-v1 file
                                 from `locag fit` (default lassen)
  allgather    Shorthand for `run --op allgather` (paper compatibility).
               Same options as run, u32 payloads.
  explain      Print an algorithm's communication schedule (the IR the
               executor runs) and its cost breakdown: per-class traffic
               and the model-predicted completion time.
               --op OP --algo NAME --regions N --ppr N --values N
               --counts C0,C1,.. (ragged per-rank counts, like `run`)
               --rank N (whose schedule to print; default 0) --machine NAME
               --fused: explain the serving-loop fusion instead (K
               allgathers ⊕ consensus allreduce as ONE round-merged,
               message-coalesced schedule) with fused-vs-sequential
               non-local traffic and predicted/measured completion.
               Extra options: --batch K --consensus-values N
  fuse         Print the full coalescing table of the serving-loop fusion:
               every merged wire message (rank, round, peer, payload,
               constituents), the fused-vs-sequential totals, and the
               staging bytes per execute that zero-copy views eliminate.
               --algo NAME --regions N --ppr N --values N --batch K
               --consensus-values N --machine NAME
  bench        Micro-bench a fixed (shape, algorithm) grid — allgather and
               reduce-scatter rows, plus a serving_rps pair (modeled fused
               serving schedule, gated; measured staged vs zero-copy
               seconds/request, never gated) — and emit a BENCH_*.json
               perf-trajectory artifact (p, n, algo, vtime, predicted,
               wall) for cross-PR regression tracking.
               --json FILE (default results/BENCH_collectives.json)
               --compare OLD.json   perf-regression gate: exit non-zero if
                                    any algorithm's vtime/predicted grew
                                    >20% vs the baseline artifact (what CI
                                    runs; wall time is never gated)
               --backend sim|proc   proc additionally executes every row on
                                    a persistent multi-process worker pool
                                    (one pool per topology shape; workers
                                    spawn + handshake once, each schedule
                                    ships once) and records the median
                                    repeat-execute wall time as a wall_proc
                                    column — carried in the artifact, never
                                    gated (default sim)
               --proc-iters N       timed executes per proc row after 2
                                    discarded warmups (default 5)
               --machine NAME
  figure       Regenerate a figure: 3 | 7 | 8 | 9 | 10 | allreduce |
               alltoall | reduce_scatter.
               Measured figures include the predicted-vs-measured overlay
               (one "(model)" series per algorithm, from the schedule IR).
               --out FILE        CSV path (default results/figN.csv)
               --max-p N         world-size cap for the sweeps (default 1024)
               --backend sim|proc   proc adds measured multi-process wall
                                    times to the measured sweeps (one
                                    persistent pool per shape, worlds up to
                                    64 ranks) as a proc_seconds CSV column
                                    and "(proc)" plot series (default sim)
  pingpong     Print the locality-class ping-pong series (Fig. 3 shape).
               --machine NAME
  fit          Measure real per-class α/β by ping-ponging OS processes over
               each proc-backend channel (shm ring = local class, Unix
               socket = non-local) and least-squares fitting eager and
               rendezvous segments; writes a locag-params-v1 JSON that
               --machine accepts everywhere (incl. model-tuned dispatch).
               The full sweep reaches 4 MiB messages (iterations scale down
               with size); underdetermined protocol segments are reported
               as typed warnings instead of silently collapsing.
               --out FILE (default results/params_fitted.json)
               --quick (reduced sweep, for smoke tests/CI)
  pattern      Print the step-by-step communication pattern (paper Figs.
               1 and 4 as text). --algo NAME --regions N --ppr N
  e2e          Tensor-parallel serving with a FUSED collective hot path:
               each chunk of --fuse-batch requests executes its allgathers,
               reduce-scatter shards and the consensus allreduce as one
               coalesced schedule through zero-copy segmented buffer
               views, with chunk c's final projections overlapped against
               chunk c+1's in-flight collective (default: model-tuned).
               --algo NAME --regions N --requests N --artifacts DIR
               --fuse-batch K (request micro-batch; default 1)
               --rs-shards N (fused reduce-scatter shards per chunk;
               default 0)
               --fused (use the fused gathered-matmul artifact)
               --staged (staging-copy execution — the conformance oracle)
               --no-pipeline (serialize chunks; finals after each finish)
               --collective-backend sim|proc (proc runs the fused hot path
               on a persistent multi-process worker pool; default sim)
               --measure-rps: synthetic serving-throughput mode (needs NO
               artifacts): run a heavy request stream twice — staged +
               serial vs zero-copy + pipelined — and report req/s for
               both plus the speedup. Extra options: --ppr N --values N
               (gather elems/request, default 4096) --rs-shards N
  validate     Cross-check every algorithm against the expected gather and
               the paper's message-count bounds. --max-p N (default 256)

ALGORITHMS (case-insensitive; see `locag algos`)
  allgather:      system-default bruck ring recursive-doubling dissemination
                  hierarchical multilane loc-bruck loc-bruck-v
                  loc-bruck-2level model-tuned
  allreduce:      recursive-doubling loc-aware rabenseifner model-tuned
                  (rabenseifner = reduce-scatter + allgather; any p, no
                  power-of-two precondition)
  alltoall:       system-default pairwise bruck loc-aware model-tuned
  reduce-scatter: ring recursive-halving loc-aware model-tuned
  allgatherv:     ring bruck loc-aware model-tuned (ragged counts)
  reduce-scatter-v: ring loc-aware model-tuned (ragged counts)

  `model-tuned` plans every candidate's schedule, scores each against the
  machine's locality-split postal model (the IR-derived cost model), and
  executes the cheapest — the adaptive counterpart to `system-default`.
"
    .to_string()
}
