//! CLI command implementations.

use crate::bench_harness::figures;
use crate::cli::Args;
use crate::collectives::{Algorithm, OpKind};
use crate::coordinator::{serve, ServeConfig};
use crate::error::{Error, Result};
use crate::model::MachineParams;
use crate::sim;
use crate::topology::{Locality, Topology};
use crate::util::fmt::seconds;

fn machine_by_name(name: &str) -> Result<MachineParams> {
    // A preset name (lassen | quartz) or the path of a `locag-params-v1`
    // JSON file — e.g. `results/params_fitted.json` from `locag fit`.
    MachineParams::by_name_or_path(name)
}

fn algo_by_name(name: &str) -> Result<Algorithm> {
    // Case-insensitive; unknown names list every valid name.
    Algorithm::parse_or_err(name)
}

/// `locag algos` — list the algorithm registries of all six operations.
pub fn algos(_args: &Args) -> Result<i32> {
    use crate::collectives::{
        AllgathervRegistry, AllreduceRegistry, AlltoallRegistry, ReduceScatterRegistry,
        ReduceScattervRegistry, Registry,
    };
    println!("registered collective algorithms (names are case-insensitive):");
    let sections: Vec<(OpKind, Vec<(&'static str, &'static str)>)> = vec![
        (OpKind::Allgather, Registry::<u32>::standard().catalog()),
        (OpKind::Allreduce, AllreduceRegistry::<u32>::standard().catalog()),
        (OpKind::Alltoall, AlltoallRegistry::<u32>::standard().catalog()),
        (OpKind::ReduceScatter, ReduceScatterRegistry::<u32>::standard().catalog()),
        (OpKind::Allgatherv, AllgathervRegistry::<u32>::standard().catalog()),
        (OpKind::ReduceScatterV, ReduceScattervRegistry::<u32>::standard().catalog()),
    ];
    for (op, catalog) in sections {
        println!("\n{op}:");
        for (name, summary) in catalog {
            println!("  {name:<20} {summary}");
        }
    }
    println!(
        "\nEach algorithm supports one-shot use and persistent plans (plan once\n\
         via the per-op registry, execute many times with zero setup or\n\
         allocation). Run any pair with `locag run --op OP --algo NAME`; the\n\
         ragged ops take per-rank sizes via `--counts 4,0,7,2`."
    );
    Ok(0)
}

/// Parse `--counts c0,c1,...` — per-rank element counts for the ragged
/// ops. Defaults to `n` on every rank; the list must name exactly `p`
/// ranks.
fn counts_arg(args: &Args, n: usize, p: usize) -> Result<crate::collectives::Counts> {
    use crate::collectives::Counts;
    let counts = match args.options.get("counts") {
        Some(s) => Counts::parse(s)?,
        None => Counts::uniform(n, p),
    };
    if counts.len() != p {
        return Err(Error::Precondition(format!(
            "--counts lists {} ranks but the topology has {p}",
            counts.len()
        )));
    }
    Ok(counts)
}

/// `locag run` — one configured run of any operation.
pub fn run_op(args: &Args) -> Result<i32> {
    let op = OpKind::parse_or_err(&args.get_str("op", "allgather"))?;
    let regions = args.get_usize("regions", 16)?;
    let ppr = args.get_usize("ppr", 8)?;
    let n = args.get_usize("values", 2)?;
    let m = machine_by_name(&args.get_str("machine", "lassen"))?;
    let topo = Topology::regions(regions, ppr);
    let default_algo = match op {
        OpKind::Allgather => "loc-bruck",
        OpKind::Allreduce | OpKind::Alltoall | OpKind::ReduceScatter => "loc-aware",
        OpKind::Allgatherv | OpKind::ReduceScatterV => "loc-aware",
    };
    let algo = args.get_str("algo", default_algo);
    // The ragged ops take per-rank counts; `--counts` is rejected up front
    // when its length disagrees with the topology.
    let counts = match op {
        OpKind::Allgatherv | OpKind::ReduceScatterV => Some(counts_arg(args, n, topo.size())?),
        _ => None,
    };
    let (algo_name, vtime, predicted, verified, trace, errors) = match op {
        OpKind::Allgather => {
            let rep = sim::run_allgather(algo_by_name(&algo)?, &topo, &m, n);
            (
                rep.algorithm.name().to_string(),
                rep.vtime,
                rep.predicted,
                rep.verified,
                rep.trace,
                rep.errors,
            )
        }
        OpKind::Allreduce => {
            let rep = sim::run_allreduce(&algo, &topo, &m, n);
            (rep.algorithm, rep.vtime, rep.predicted, rep.verified, rep.trace, rep.errors)
        }
        OpKind::Alltoall => {
            let rep = sim::run_alltoall(&algo, &topo, &m, n);
            (rep.algorithm, rep.vtime, rep.predicted, rep.verified, rep.trace, rep.errors)
        }
        OpKind::ReduceScatter => {
            let rep = sim::run_reduce_scatter(&algo, &topo, &m, n);
            (rep.algorithm, rep.vtime, rep.predicted, rep.verified, rep.trace, rep.errors)
        }
        OpKind::Allgatherv => {
            let rep = sim::run_allgatherv(&algo, &topo, &m, counts.as_ref().expect("set above"));
            (rep.algorithm, rep.vtime, rep.predicted, rep.verified, rep.trace, rep.errors)
        }
        OpKind::ReduceScatterV => {
            let rep =
                sim::run_reduce_scatter_v(&algo, &topo, &m, counts.as_ref().expect("set above"));
            (rep.algorithm, rep.vtime, rep.predicted, rep.verified, rep.trace, rep.errors)
        }
    };
    let sizing = match &counts {
        Some(c) => format!("counts [{c}]"),
        None => format!("{n} values/rank"),
    };
    println!(
        "{op} / {algo_name} on {} ranks ({regions} regions x {ppr}), {sizing} [{}]",
        topo.size(),
        m.name
    );
    println!("modeled time: {}", seconds(vtime));
    println!("predicted:    {} (from the schedule IR)", seconds(predicted));
    println!("verified:     {verified}");
    print!("{}", trace.table());
    if !verified {
        for e in &errors {
            eprintln!("error: {e}");
        }
        return Ok(1);
    }
    Ok(0)
}

/// `locag quickstart` — the paper's Example 2.1 walkthrough.
pub fn quickstart(_args: &Args) -> Result<i32> {
    println!("Example 2.1: 16 processes, 4 per region; 1 u32 value each.\n");
    let topo = Topology::regions(4, 4);
    let m = MachineParams::lassen();
    println!(
        "{:<18} {:>11} {:>14} {:>13} {:>12}",
        "algorithm", "max NL msgs", "max NL bytes", "modeled time", "verified"
    );
    for algo in [
        Algorithm::Bruck,
        Algorithm::Pat,
        Algorithm::Ring,
        Algorithm::Hierarchical,
        Algorithm::Multilane,
        Algorithm::LocalityBruck,
    ] {
        let rep = sim::run_allgather(algo, &topo, &m, 1);
        println!(
            "{:<18} {:>11} {:>14} {:>13} {:>12}",
            algo.name(),
            rep.trace.max_nonlocal_msgs(),
            rep.trace.max_nonlocal_bytes(),
            seconds(rep.vtime),
            rep.verified
        );
    }
    println!(
        "\nPaper §3: standard Bruck sends 4 non-local messages (15 values) per\n\
         rank; the locality-aware Bruck sends 1 non-local message (4 values).\n"
    );
    println!("Extended to 64 processes / 16 regions (paper Fig. 6):");
    let topo64 = Topology::regions(16, 4);
    for algo in [Algorithm::Bruck, Algorithm::LocalityBruck] {
        let rep = sim::run_allgather(algo, &topo64, &m, 1);
        println!(
            "  {:<12} max non-local msgs {} modeled {}",
            algo.name(),
            rep.trace.max_nonlocal_msgs(),
            seconds(rep.vtime)
        );
    }
    println!(
        "\n§6 extensions — the same plan-once registry covers allreduce,\n\
         alltoall and reduce-scatter (`locag algos`, `locag run --op ...`);\n\
         on the 16-rank example:"
    );
    let topo = Topology::regions(4, 4);
    for (op, baseline, aware) in [
        (OpKind::Allreduce, "recursive-doubling", "loc-aware"),
        (OpKind::Allreduce, "rabenseifner", "loc-rabenseifner"),
        (OpKind::Alltoall, "bruck", "loc-aware"),
        (OpKind::ReduceScatter, "ring", "loc-aware"),
        (OpKind::ReduceScatter, "pat", "loc-aware"),
    ] {
        let run_one = |name: &str| match op {
            OpKind::Allreduce => sim::run_allreduce(name, &topo, &m, 2),
            OpKind::ReduceScatter => sim::run_reduce_scatter(name, &topo, &m, 2),
            _ => sim::run_alltoall(name, &topo, &m, 2),
        };
        let (b, a) = (run_one(baseline), run_one(aware));
        println!(
            "  {op:<14} {baseline:<20} max NL msgs {:>2}   {aware:<10} max NL msgs {:>2}",
            b.trace.max_nonlocal_msgs(),
            a.trace.max_nonlocal_msgs()
        );
    }
    println!(
        "\nRagged sizes — every rank may contribute a DIFFERENT count\n\
         (allgatherv / reduce-scatter-v, `locag run --op allgatherv\n\
         --counts 4,0,7,2`). Locality still fixes the exchange structure;\n\
         the counts only size the payloads, so zero-count ranks participate\n\
         in every round and the non-local message bounds survive intact.\n\
         Skewed counts (rank r contributes r mod 5) on the 16-rank example:"
    );
    let counts = crate::collectives::Counts::new((0..topo.size()).map(|r| r % 5).collect());
    for algo in ["ring", "bruck", "loc-aware"] {
        let rep = sim::run_allgatherv(algo, &topo, &m, &counts);
        println!(
            "  allgatherv/{:<10} max NL msgs {:>2} modeled {}",
            algo,
            rep.trace.max_nonlocal_msgs(),
            seconds(rep.vtime)
        );
    }
    let rsv = sim::run_reduce_scatter_v("loc-aware", &topo, &m, &counts);
    println!(
        "  reduce-scatter-v/loc-aware max NL msgs {:>2} modeled {}",
        rsv.trace.max_nonlocal_msgs(),
        seconds(rsv.vtime)
    );
    println!(
        "\nEvery algorithm is a communication-schedule (IR) builder executed\n\
         by one generic interpreter. Inspect any schedule and its modeled\n\
         cost with `locag explain --algo loc-bruck --regions 4 --ppr 4`\n\
         (it also prices every candidate in the op's model-tuned pool —\n\
         the crossover table; `--sweep` prints the winner per message\n\
         size), and let the cost model pick the algorithm with\n\
         `locag run --algo model-tuned` (scores every candidate schedule\n\
         against the machine's postal parameters, plans the cheapest):"
    );
    let rep = sim::run_allgather(Algorithm::ModelTuned, &topo, &m, 1);
    println!(
        "  model-tuned @ 4x4: modeled {} | predicted {} | max NL msgs {}",
        seconds(rep.vtime),
        seconds(rep.predicted),
        rep.trace.max_nonlocal_msgs()
    );
    println!(
        "\nConcurrent collectives fuse into ONE schedule (`locag fuse`,\n\
         `locag explain --fused`, `locag e2e --fuse-batch K`): rounds are\n\
         merged across plans and same-destination sends coalesce into one\n\
         wire message — the paper's aggregation idea lifted across whole\n\
         collectives. The serving loop's allgather ⊕ consensus allreduce\n\
         on the 4x4 example:"
    );
    let specs = vec![
        crate::collectives::FuseSpec::new(OpKind::Allgather, "loc-bruck", 1),
        crate::collectives::FuseSpec::new(OpKind::Allreduce, "loc-aware", 2),
    ];
    let fr = sim::run_fused(&specs, &topo, &m);
    println!(
        "  fused:      max NL msgs {} modeled {}\n  sequential: max NL msgs {} modeled {}",
        fr.fused_trace.max_nonlocal_msgs(),
        seconds(fr.fused_vtime),
        fr.seq_trace.max_nonlocal_msgs(),
        seconds(fr.seq_vtime)
    );
    println!(
        "\nBackends — every schedule above runs on either interpreter:\n\
         \n\
         * sim (default): all ranks are threads in this process, timed by\n\
           the virtual postal clock. Deterministic, fast, exact message\n\
           accounting — what the figures, the perf gate and `validate` use.\n\
         * proc (`--backend proc` on `locag bench` / `locag figure`,\n\
           `--collective-backend proc` on `locag e2e`): one OS process per\n\
           rank in a persistent pool. Workers spawn and complete the\n\
           channel handshake ONCE; each schedule ships to them once; every\n\
           later execute reuses the same shared-memory rings (region-local\n\
           pairs) and Unix sockets (cross-region pairs) with only input\n\
           and output deltas crossing the control path — the paper's local\n\
           vs non-local split made physical, plan-once/execute-many.\n\
           Outputs are bit-identical to sim; `wall_proc` is the median\n\
           repeat-execute time, never a per-row spawn+handshake+run.\n\
         \n\
         To ground the cost model in measurement instead of the built-in\n\
         presets, run `locag fit [--quick] --out results/params_fitted.json`:\n\
         it ping-pongs worker processes over each channel class, fits\n\
         eager/rendezvous α/β per class, and writes a params file any\n\
         `--machine` flag accepts — including `model-tuned` dispatch, which\n\
         then picks algorithms against YOUR measured machine."
    );
    println!(
        "\nServing throughput — the serving loop's fused collective executes\n\
         through zero-copy segmented buffer views (no staging memcpys;\n\
         `locag fuse` prints the bytes eliminated) and overlaps each\n\
         chunk's final projections with the next chunk's in-flight\n\
         collective (cross-chunk software pipelining over double-buffered\n\
         output banks; the consensus allreduce rides one collective\n\
         behind). Measure both effects on a synthetic heavy load — no\n\
         artifacts needed:\n\
         \n\
           locag e2e --measure-rps --fuse-batch 4             staged vs zero-copy req/s\n\
           locag e2e --measure-rps --collective-backend proc  same, across OS processes\n\
         \n\
         `locag bench` records the pair as `serving_rps` rows in the perf\n\
         artifact, so the CI gate pins the win."
    );
    Ok(0)
}

/// `locag allgather` — one configured run.
pub fn allgather(args: &Args) -> Result<i32> {
    let algo = algo_by_name(&args.get_str("algo", "loc-bruck"))?;
    let regions = args.get_usize("regions", 16)?;
    let ppr = args.get_usize("ppr", 8)?;
    let n = args.get_usize("values", 2)?;
    let m = machine_by_name(&args.get_str("machine", "lassen"))?;
    let topo = Topology::regions(regions, ppr);
    let rep = sim::run_allgather(algo, &topo, &m, n);
    println!(
        "{} on {} ranks ({regions} regions x {ppr}), {n} u32 values/rank [{}]",
        algo.name(),
        topo.size(),
        m.name
    );
    println!("modeled time: {}", seconds(rep.vtime));
    println!("verified:     {}", rep.verified);
    print!("{}", rep.trace.table());
    if !rep.verified {
        for e in &rep.errors {
            eprintln!("error: {e}");
        }
        return Ok(1);
    }
    Ok(0)
}

/// `locag figure <id>` — regenerate one paper figure. `--backend proc`
/// adds measured multi-process wall times (one persistent pool per
/// topology shape, plan-once/execute-many) to the measured sweeps as a
/// `proc_seconds` CSV column and `(proc)` plot series.
pub fn figure(args: &Args) -> Result<i32> {
    use crate::transport::Backend;

    let id = args
        .positional
        .first()
        .ok_or_else(|| Error::Precondition("figure needs an id: 3|7|8|9|10".into()))?
        .clone();
    let default_out = format!("results/fig{id}.csv");
    let out = args.get_str("out", &default_out);
    if let Some(parent) = std::path::Path::new(&out).parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let max_p = args.get_usize("max-p", 1024)?;
    let backend = Backend::parse_or_err(&args.get_str("backend", "sim"))?;
    if backend == Backend::Proc && matches!(id.as_str(), "3" | "7" | "8") {
        eprintln!("warning: figure {id} is model-derived; --backend proc has no effect on it");
    }
    let fig = match id.as_str() {
        "3" => figures::fig3(&out)?,
        "7" => figures::fig7(&out)?,
        "8" => figures::fig8(&out)?,
        "9" => figures::fig9(&out, max_p, backend)?,
        "10" => figures::fig10(&out, max_p, backend)?,
        "allreduce" => figures::fig_allreduce(&out, max_p, backend)?,
        "alltoall" => figures::fig_alltoall(&out, max_p, backend)?,
        "reduce-scatter" | "reduce_scatter" => figures::fig_reduce_scatter(&out, max_p, backend)?,
        other => {
            return Err(Error::Precondition(format!(
                "unknown figure '{other}' (expected 3|7|8|9|10|allreduce|alltoall|reduce_scatter)"
            )))
        }
    };
    println!("{}", fig.plot());
    println!("CSV written to {out}");
    Ok(0)
}

/// Render one rank's schedule table (shared by `explain` and `fuse`).
fn print_schedule(sched: &crate::collectives::Schedule, rank: usize, topo: &Topology) {
    use crate::collectives::schedule::BufId;
    use crate::collectives::{Slice, Step};
    println!(
        "schedule of rank {rank}: {} rounds, {} steps, {} tags, {} scratch buffers\n",
        sched.rounds.len(),
        sched.num_steps(),
        sched.tags,
        sched.scratch.len()
    );
    let slice = |s: &Slice| -> String {
        let buf = match s.buf {
            BufId::Input => "in".to_string(),
            BufId::Output => "out".to_string(),
            BufId::Scratch(i) => format!("s{i}"),
        };
        format!("{buf}[{}..{}]", s.off, s.off + s.len)
    };
    let peer_class = |r: usize| topo.classify(rank, r).label();
    for (ri, round) in sched.rounds.iter().enumerate() {
        println!("round {ri}: {}", round.label);
        for step in &round.steps {
            match step {
                Step::Send { to, src, tag, pad } => println!(
                    "  send     -> P{to:<4} {:>8} B  tag {tag}  {} [{}]",
                    sched.wire_bytes(src.len, *pad),
                    slice(src),
                    peer_class(*to),
                ),
                Step::Recv { from, dst, tag, pad } => println!(
                    "  recv     <- P{from:<4} {:>8} B  tag {tag}  {} [{}]",
                    sched.wire_bytes(dst.len, *pad),
                    slice(dst),
                    peer_class(*from),
                ),
                Step::SendRecv { to, src, from, dst, tag, pad } => println!(
                    "  sendrecv -> P{to} / <- P{from}  {:>8} B  tag {tag}  {} -> {} [{}]",
                    sched.wire_bytes(src.len, *pad),
                    slice(src),
                    slice(dst),
                    peer_class(*to),
                ),
                Step::CopyLocal { src, dst } => {
                    println!("  copy     {} -> {}", slice(src), slice(dst))
                }
                Step::Reduce { src, dst } => {
                    println!("  reduce   {} += into {}", slice(src), slice(dst))
                }
                Step::Rotate { src, dst, block, shift } => println!(
                    "  rotate   {} -> {} (block {block}, shift {shift})",
                    slice(src),
                    slice(dst)
                ),
            }
        }
    }
}

/// The serving-loop fusion specs shared by `locag fuse` and
/// `locag explain --fused`: `batch` allgathers plus (when
/// `consensus_n > 0`) one consensus allreduce.
fn serving_specs(
    algo: &str,
    n: usize,
    batch: usize,
    consensus_n: usize,
) -> Vec<crate::collectives::FuseSpec> {
    use crate::collectives::FuseSpec;
    let mut specs: Vec<FuseSpec> =
        (0..batch).map(|_| FuseSpec::new(OpKind::Allgather, algo, n)).collect();
    if consensus_n > 0 {
        specs.push(FuseSpec::new(OpKind::Allreduce, "loc-aware", consensus_n));
    }
    specs
}

/// `locag explain --fused` — the serving-loop fusion (K allgathers ⊕ the
/// consensus allreduce) as one schedule: one rank's fused schedule table,
/// the coalescing summary, and fused-vs-sequential traffic and predicted
/// completion, with the measured virtual time shown against the IR
/// prediction (they are equal — the single-plan invariant extends to
/// fused schedules).
fn explain_fused(args: &Args) -> Result<i32> {
    use crate::collectives::fuse;
    use crate::collectives::schedule::WorldView;
    use crate::model::cost;

    let algo = args.get_str("algo", "loc-bruck");
    let regions = args.get_usize("regions", 2)?;
    let ppr = args.get_usize("ppr", 8)?;
    let n = args.get_usize("values", 2)?;
    let batch = args.get_usize("batch", 1)?.max(1);
    // Mirror the serving loop: two consensus probes per batched request.
    let consensus_n = args.get_usize("consensus-values", 2 * batch)?;
    let rank = args.get_usize("rank", 0)?;
    let m = machine_by_name(&args.get_str("machine", "lassen"))?;
    let topo = Topology::regions(regions, ppr);
    let p = topo.size();
    if rank >= p {
        return Err(Error::Precondition(format!("--rank {rank} outside 0..{p}")));
    }
    let view = WorldView::world(&topo);
    let specs = serving_specs(&algo, n, batch, consensus_n);
    // u64 payloads (8 B), like the sweep engine.
    let (fused, stats) = fuse::fuse_world(&specs, &view, 8, &m)?;
    println!("fused plan on {p} ranks ({regions} regions x {ppr}) [{}]:", m.name);
    for (i, s) in specs.iter().enumerate() {
        println!("  constituent {i}: {}", s.label());
    }
    println!();
    print_schedule(&fused[rank], rank, &topo);

    let merged = stats.iter().flat_map(|s| &s.merged).filter(|mm| mm.send).count();
    let before: usize = stats.iter().map(|s| s.sends_before).sum();
    let after: usize = stats.iter().map(|s| s.sends_after).sum();
    println!(
        "\ncoalescing: {before} wire messages -> {after} ({merged} merged sends; \
         `locag fuse` prints the full table)"
    );

    let mut worlds = Vec::new();
    for s in specs.iter().filter(|s| s.n > 0) {
        worlds.push(fuse::build_world(s, &view, 8, &m)?);
    }
    let rep = cost::evaluate_fusion(&fused, &worlds, &topo, &view.world_of, &m)?;
    println!("\nfused vs sequential (IR-derived, machine '{}'):", m.name);
    println!(
        "  non-local msgs (worst rank): fused {} vs sequential {}",
        rep.fused.max_nonlocal_msgs(),
        rep.sequential.max_nonlocal_msgs()
    );
    println!("  non-local msgs saved (all ranks): {}", rep.nonlocal_msgs_saved());
    println!(
        "  predicted completion: fused {} vs sequential {} (saving {})",
        seconds(rep.fused.predicted),
        seconds(rep.sequential.predicted),
        seconds(rep.predicted_saving())
    );

    let run = sim::run_fused(&specs, &topo, &m);
    if !run.verified {
        for e in &run.errors {
            eprintln!("error: {e}");
        }
        return Ok(1);
    }
    println!(
        "\nmeasured (virtual transport): fused {} (predicted {}), sequential {}",
        seconds(run.fused_vtime),
        seconds(run.fused_predicted),
        seconds(run.seq_vtime)
    );
    Ok(0)
}

/// `locag fuse` — print the full coalescing table of the serving-loop
/// fusion: every merged wire message (round, peer, direction, payload,
/// constituents) plus the fused-vs-sequential totals.
pub fn fuse_cmd(args: &Args) -> Result<i32> {
    use crate::collectives::fuse;
    use crate::collectives::schedule::WorldView;
    use crate::model::cost;

    let algo = args.get_str("algo", "loc-bruck");
    let regions = args.get_usize("regions", 2)?;
    let ppr = args.get_usize("ppr", 8)?;
    let n = args.get_usize("values", 2)?;
    let batch = args.get_usize("batch", 1)?.max(1);
    // Mirror the serving loop: two consensus probes per batched request.
    let consensus_n = args.get_usize("consensus-values", 2 * batch)?;
    let m = machine_by_name(&args.get_str("machine", "lassen"))?;
    let topo = Topology::regions(regions, ppr);
    let view = WorldView::world(&topo);
    let specs = serving_specs(&algo, n, batch, consensus_n);
    let (fused, stats) = fuse::fuse_world(&specs, &view, 8, &m)?;
    println!(
        "fusing {} collectives on {} ranks ({regions} regions x {ppr}) [{}]:",
        specs.len(),
        topo.size(),
        m.name
    );
    for (i, s) in specs.iter().enumerate() {
        println!("  constituent {i}: {}", s.label());
    }
    println!(
        "\n{:<5} {:>5} {:>5} {:<4} {:>10} {:>7} {:>5}  constituents",
        "rank", "round", "peer", "dir", "payload", "pad", "tag"
    );
    let mut any = false;
    for (r, st) in stats.iter().enumerate() {
        for mm in &st.merged {
            any = true;
            println!(
                "{:<5} {:>5} {:>5} {:<4} {:>8} B {:>5} B {:>5}  {:?}",
                r,
                mm.round,
                mm.peer,
                if mm.send { "send" } else { "recv" },
                mm.elems * 8,
                mm.pad,
                mm.tag,
                mm.parts
            );
        }
    }
    if !any {
        println!("(no messages were coalesced on this configuration)");
    }
    let before: usize = stats.iter().map(|s| s.sends_before).sum();
    let after: usize = stats.iter().map(|s| s.sends_after).sum();
    println!("\nwire messages (all ranks): {before} sequential -> {after} fused");
    // What the zero-copy view path saves: a staged execute memcpys every
    // constituent through the composite staging buffers on the way in and
    // out; `FusedPlan::execute_view` runs over segmented views instead.
    let staging: usize = stats.iter().map(|s| s.staging_bytes).sum();
    let staging_worst = stats.iter().map(|s| s.staging_bytes).max().unwrap_or(0);
    println!(
        "staging bytes eliminated by zero-copy views: {staging} B/execute across all \
         ranks ({staging_worst} B on the busiest rank)"
    );

    let mut worlds = Vec::new();
    for s in specs.iter().filter(|s| s.n > 0) {
        worlds.push(fuse::build_world(s, &view, 8, &m)?);
    }
    let rep = cost::evaluate_fusion(&fused, &worlds, &topo, &view.world_of, &m)?;
    println!(
        "non-local msgs (worst rank): fused {} vs sequential {} | predicted saving {}",
        rep.fused.max_nonlocal_msgs(),
        rep.sequential.max_nonlocal_msgs(),
        seconds(rep.predicted_saving())
    );
    Ok(0)
}

/// `locag explain` — print an algorithm's communication schedule and its
/// IR-derived cost breakdown: the schedule table of one rank, per-class
/// traffic, the predicted completion time, and the candidate crossover
/// table (every candidate of the op's model-tuned pool priced at this
/// shape, winner marked). With `--sweep [MAX_N]`, print the model-tuned
/// winner per message size over a log-spaced n sweep instead — the
/// PAT / ring / loc-aware crossover without plotting. With `--fused`,
/// explain the serving-loop fusion instead ([`explain_fused`]).
pub fn explain(args: &Args) -> Result<i32> {
    use crate::collectives::schedule::{Schedule, WorldView};
    use crate::collectives::{allgatherv, model_tuned, reduce_scatter_v, schedule, OpKind};
    use crate::model::cost;

    if args.get_bool("fused") {
        return explain_fused(args);
    }

    let op = OpKind::parse_or_err(&args.get_str("op", "allgather"))?;
    let default_algo = match op {
        OpKind::Allgather => "loc-bruck",
        OpKind::Allreduce | OpKind::Alltoall | OpKind::ReduceScatter => "loc-aware",
        OpKind::Allgatherv | OpKind::ReduceScatterV => "loc-aware",
    };
    let algo = args.get_str("algo", default_algo);
    let regions = args.get_usize("regions", 4)?;
    let ppr = args.get_usize("ppr", 4)?;
    let n = args.get_usize("values", 2)?;
    let rank = args.get_usize("rank", 0)?;
    let m = machine_by_name(&args.get_str("machine", "lassen"))?;
    let topo = Topology::regions(regions, ppr);
    let p = topo.size();
    if rank >= p {
        return Err(Error::Precondition(format!("--rank {rank} outside 0..{p}")));
    }
    let view = WorldView::world(&topo);
    // Element sizes mirror the sweep engine's payloads (u32 allgather,
    // u64 everywhere else).
    let esz = match op {
        OpKind::Allgather => 4usize,
        _ => 8,
    };
    // Per-rank counts for the ragged ops (`--counts`; uniform `n` when
    // absent). Harmlessly uniform for the classic ops.
    let vcounts = counts_arg(args, n, p)?;
    let is_ragged = matches!(op, OpKind::Allgatherv | OpKind::ReduceScatterV);
    let build_one = |name: &str, r: usize| -> Result<Schedule> {
        match op {
            OpKind::Allgather => {
                schedule::build_allgather(Algorithm::parse_or_err(name)?, &view, r, n, esz)
            }
            OpKind::Allreduce => schedule::build_allreduce(name, &view, r, n, esz),
            OpKind::Alltoall => schedule::build_alltoall(name, &view, r, n, esz),
            OpKind::ReduceScatter => schedule::build_reduce_scatter(name, &view, r, n, esz),
            OpKind::Allgatherv => {
                allgatherv::build_allgatherv(name, &view, r, vcounts.as_slice(), esz)
            }
            OpKind::ReduceScatterV => {
                reduce_scatter_v::build_reduce_scatter_v(name, &view, r, vcounts.as_slice(), esz)
            }
        }
    };
    let world: Vec<usize> = (0..p).collect();
    // The op's model-tuned candidate pool, by registry name. Shared by the
    // crossover table and the `--sweep` mode.
    let candidates: Vec<String> = match op {
        OpKind::Allgather => {
            model_tuned::ALLGATHER_CANDIDATES.iter().map(|a| a.name().to_string()).collect()
        }
        OpKind::Allreduce => {
            model_tuned::ALLREDUCE_CANDIDATES.iter().map(|s| s.to_string()).collect()
        }
        OpKind::Alltoall => {
            model_tuned::ALLTOALL_CANDIDATES.iter().map(|s| s.to_string()).collect()
        }
        OpKind::ReduceScatter => {
            model_tuned::REDUCE_SCATTER_CANDIDATES.iter().map(|s| s.to_string()).collect()
        }
        OpKind::Allgatherv => {
            model_tuned::ALLGATHERV_CANDIDATES.iter().map(|s| s.to_string()).collect()
        }
        OpKind::ReduceScatterV => {
            model_tuned::REDUCE_SCATTER_V_CANDIDATES.iter().map(|s| s.to_string()).collect()
        }
    };

    if let Some(sweep) = args.options.get("sweep") {
        // `--sweep` alone sweeps to 64 Ki elements; `--sweep N` stops at N.
        let max_n = sweep.parse::<usize>().unwrap_or(1 << 16).max(1);
        println!(
            "model-tuned winner per message size: {op} on {p} ranks \
             ({regions} regions x {ppr}) [{}]",
            m.name
        );
        println!("{:>9} {:>11}  {:<26} {:>13}", "n", "bytes/rank", "winner", "predicted");
        let mut n_s = 1usize;
        loop {
            // The sweep varies a uniform per-rank size even for the ragged
            // ops — it charts crossover vs message size, not skew.
            let uni = vec![n_s; p];
            let (winner, scheds) = match op {
                OpKind::Allgather => model_tuned::pick_allgather(&view, &m, n_s, esz)?,
                OpKind::Allreduce => model_tuned::pick_allreduce(&view, &m, n_s, esz)?,
                OpKind::Alltoall => model_tuned::pick_alltoall(&view, &m, n_s, esz)?,
                OpKind::ReduceScatter => model_tuned::pick_reduce_scatter(&view, &m, n_s, esz)?,
                OpKind::Allgatherv => model_tuned::pick_allgatherv(&view, &m, &uni, esz)?,
                OpKind::ReduceScatterV => {
                    model_tuned::pick_reduce_scatter_v(&view, &m, &uni, esz)?
                }
            };
            let t = cost::predict(&scheds, &topo, &world, &m)?;
            println!("{:>9} {:>11}  {:<26} {:>13}", n_s, n_s * esz, winner, seconds(t));
            if n_s >= max_n {
                break;
            }
            n_s = (n_s * 4).min(max_n);
        }
        return Ok(0);
    }

    let scheds: Vec<Schedule> = if algo.eq_ignore_ascii_case("model-tuned") {
        let (winner, scheds) = match op {
            OpKind::Allgather => model_tuned::pick_allgather(&view, &m, n, esz)?,
            OpKind::Allreduce => model_tuned::pick_allreduce(&view, &m, n, esz)?,
            OpKind::Alltoall => model_tuned::pick_alltoall(&view, &m, n, esz)?,
            OpKind::ReduceScatter => model_tuned::pick_reduce_scatter(&view, &m, n, esz)?,
            OpKind::Allgatherv => {
                model_tuned::pick_allgatherv(&view, &m, vcounts.as_slice(), esz)?
            }
            OpKind::ReduceScatterV => {
                model_tuned::pick_reduce_scatter_v(&view, &m, vcounts.as_slice(), esz)?
            }
        };
        println!("model-tuned selection: {winner}");
        scheds
    } else {
        (0..p).map(|r| build_one(&algo, r)).collect::<Result<_>>()?
    };

    let sched = &scheds[rank];
    let sizing =
        if is_ragged { format!("counts [{vcounts}]") } else { format!("{n} values/rank") };
    println!(
        "{op} / {} on {p} ranks ({regions} regions x {ppr}), {sizing} [{}]",
        sched.label, m.name
    );
    print_schedule(sched, rank, &topo);
    let rep = cost::evaluate(&scheds, &topo, &world, &m)?;
    let mine = &rep.per_rank[rank];
    println!("\ncost breakdown (IR-derived, machine '{}'):", m.name);
    println!(
        "  rank {rank}:  {} local msgs / {} B   {} non-local msgs / {} B",
        mine.local_msgs, mine.local_bytes, mine.nonlocal_msgs, mine.nonlocal_bytes
    );
    println!(
        "  worst rank: {} non-local msgs, {} non-local B",
        rep.max_nonlocal_msgs(),
        rep.max_nonlocal_bytes()
    );
    println!("  predicted completion: {}", seconds(rep.predicted));

    // Crossover table: price every candidate in the op's model-tuned pool
    // at this exact (p, ppr, n) point. The winner marked here is what
    // `--algo model-tuned` plans (same candidate order, same tie-break);
    // candidates whose plan-time preconditions reject the shape say so.
    println!("\ncandidate crossover at this shape (model-tuned pool):");
    let mut priced: Vec<(String, std::result::Result<f64, String>)> = Vec::new();
    let mut best: Option<(f64, usize)> = None;
    for name in &candidates {
        let res = (0..p)
            .map(|r| build_one(name, r))
            .collect::<Result<Vec<Schedule>>>()
            .and_then(|s| cost::predict(&s, &topo, &world, &m));
        match res {
            Ok(t) => {
                if best.map_or(true, |(bt, _)| t < bt) {
                    best = Some((t, priced.len()));
                }
                priced.push((name.clone(), Ok(t)));
            }
            Err(e) => priced.push((name.clone(), Err(e.to_string()))),
        }
    }
    for (i, (name, res)) in priced.iter().enumerate() {
        match res {
            Ok(t) => println!(
                "  {:<26} {:>13}{}",
                name,
                seconds(*t),
                if best.map_or(false, |(_, bi)| bi == i) { "   <-- winner" } else { "" }
            ),
            Err(msg) => println!("  {:<26} {:>13}   rejected: {msg}", name, "-"),
        }
    }
    Ok(0)
}

/// `locag bench` — micro-bench a set of (shape, algorithm) points across
/// all four ops (allgather, reduce-scatter, allreduce, alltoall), emit a
/// `BENCH_*.json` perf-trajectory
/// artifact, and (with `--compare OLD.json`) run the perf-regression gate
/// against a baseline artifact: any algorithm whose deterministic
/// `vtime`/`predicted` regressed by more than 20% fails the command —
/// exactly what the CI gate step runs, reproducible locally.
pub fn bench(args: &Args) -> Result<i32> {
    use crate::bench_harness::perf_gate::{self, BenchRow};
    use crate::transport::{pool_median_wall, Backend, ProcConfig, ProcJob, ProcPool};

    let path = args.get_str("json", "results/BENCH_collectives.json");
    if let Some(parent) = std::path::Path::new(&path).parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let machine_name = args.get_str("machine", "lassen");
    let m = machine_by_name(&machine_name)?;
    let backend = Backend::parse_or_err(&args.get_str("backend", "sim"))?;
    let proc_iters = args.get_usize("proc-iters", 5)?.max(1);
    // Discarded executes per proc row before the timed iterations.
    const PROC_WARMUP: usize = 2;
    let ag_algos = [
        Algorithm::SystemDefault,
        Algorithm::Bruck,
        Algorithm::Pat,
        Algorithm::Ring,
        Algorithm::LocalityBruck,
        Algorithm::ModelTuned,
    ];
    let rs_algos = ["ring", "recursive-halving", "pat", "loc-aware", "model-tuned"];
    let ar_algos =
        ["recursive-doubling", "loc-aware", "rabenseifner", "loc-rabenseifner", "model-tuned"];
    let a2a_algos = ["pairwise", "bruck", "loc-aware", "model-tuned"];
    let shapes = [(2usize, 2usize), (4, 4), (8, 4), (4, 8)];
    let ns = [2usize, 256];
    let mut rows: Vec<BenchRow> = Vec::new();
    println!(
        "{:<14} {:<18} {:>5} {:>5} {:>5} {:>13} {:>13} {:>9}{}",
        "op",
        "algorithm",
        "p",
        "n",
        "ok",
        "vtime",
        "predicted",
        "wall",
        if backend == Backend::Proc { "  wall_proc" } else { "" }
    );
    let mut record = |row: BenchRow| {
        let wp = match row.wall_proc {
            Some(w) => format!(" {:>8.1}ms", w * 1e3),
            None => String::new(),
        };
        println!(
            "{:<14} {:<18} {:>5} {:>5} {:>5} {:>13} {:>13} {:>8.1}ms{wp}",
            row.op,
            row.algo,
            row.p,
            row.n,
            row.verified,
            seconds(row.vtime),
            seconds(row.predicted),
            row.wall * 1e3
        );
        rows.push(row);
    };
    // With `--backend proc` each row ALSO executes across real OS
    // processes. ONE persistent pool per topology shape serves every proc
    // row of that shape: workers spawn and complete the channel handshake
    // once, each row ships its schedule once, then runs PROC_WARMUP
    // discarded + `--proc-iters` timed executes over the same shm rings
    // and sockets — `wall_proc` is the median timed execute (the
    // plan-once/execute-many hot path), never a spawn+handshake+run. The
    // deterministic gated metrics stay sim-derived either way; a row the
    // pool cannot run only costs a warning, and a poisoned pool (worker
    // death, deadline) is dropped so the next row respawns it.
    for (regions, ppr) in shapes {
        let topo = Topology::regions(regions, ppr);
        let mut pool: Option<ProcPool> = None;
        let mut proc_wall = |op: OpKind, algo: &str, n: usize| -> Option<f64> {
            if backend != Backend::Proc {
                return None;
            }
            if pool.is_none() {
                match ProcPool::spawn(regions, ppr, &machine_name, &ProcConfig::default()) {
                    Ok(p) => pool = Some(p),
                    Err(e) => {
                        eprintln!("warning: proc pool {regions}x{ppr} failed to spawn: {e}");
                        return None;
                    }
                }
            }
            let job = ProcJob::Single { op, algo: algo.to_string(), n, elem_bytes: 8 };
            let pl = pool.as_mut().expect("spawned above");
            match pool_median_wall(pl, &job, PROC_WARMUP, proc_iters) {
                Ok(w) => Some(w),
                Err(e) => {
                    eprintln!(
                        "warning: proc backend skipped {op}/{algo} {regions}x{ppr} n={n}: {e}"
                    );
                    pool = None;
                    None
                }
            }
        };
        for n in ns {
            for algo in ag_algos {
                let rep = sim::run_allgather(algo, &topo, &m, n);
                record(BenchRow {
                    op: "allgather".to_string(),
                    algo: algo.name().to_string(),
                    regions,
                    ppr,
                    p: rep.p,
                    n: rep.n,
                    vtime: rep.vtime,
                    predicted: rep.predicted,
                    wall: rep.wall,
                    wall_proc: proc_wall(OpKind::Allgather, algo.name(), n),
                    verified: rep.verified,
                });
            }
            for algo in rs_algos {
                let rep = sim::run_reduce_scatter(algo, &topo, &m, n);
                record(BenchRow {
                    op: "reduce-scatter".to_string(),
                    algo: algo.to_string(),
                    regions,
                    ppr,
                    p: rep.p,
                    n: rep.n,
                    vtime: rep.vtime,
                    predicted: rep.predicted,
                    wall: rep.wall,
                    wall_proc: proc_wall(OpKind::ReduceScatter, algo, n),
                    verified: rep.verified,
                });
            }
            for algo in ar_algos {
                let rep = sim::run_allreduce(algo, &topo, &m, n);
                record(BenchRow {
                    op: "allreduce".to_string(),
                    algo: algo.to_string(),
                    regions,
                    ppr,
                    p: rep.p,
                    n: rep.n,
                    vtime: rep.vtime,
                    predicted: rep.predicted,
                    wall: rep.wall,
                    wall_proc: proc_wall(OpKind::Allreduce, algo, n),
                    verified: rep.verified,
                });
            }
            for algo in a2a_algos {
                let rep = sim::run_alltoall(algo, &topo, &m, n);
                record(BenchRow {
                    op: "alltoall".to_string(),
                    algo: algo.to_string(),
                    regions,
                    ppr,
                    p: rep.p,
                    n: rep.n,
                    vtime: rep.vtime,
                    predicted: rep.predicted,
                    wall: rep.wall,
                    wall_proc: proc_wall(OpKind::Alltoall, algo, n),
                    verified: rep.verified,
                });
            }
        }
        if let Some(mut p) = pool.take() {
            let _ = p.shutdown();
        }
    }
    // Ragged rows: one skewed allgatherv / reduce-scatter-v point per
    // registered variant (rank r contributes (3r) mod 7 elements — zero on
    // some ranks). New rows are warn-only in the perf gate until a
    // baseline carrying them lands; with `--backend proc` the same pool
    // machinery times the ragged job (ProcJob::SingleV) too.
    {
        use crate::collectives::Counts;
        let (regions, ppr) = (4usize, 4usize);
        let topo = Topology::regions(regions, ppr);
        let counts = Counts::new((0..topo.size()).map(|r| (r * 3) % 7).collect());
        let mut pool: Option<ProcPool> = None;
        let mut proc_wall = |op: OpKind, algo: &str| -> Option<f64> {
            if backend != Backend::Proc {
                return None;
            }
            if pool.is_none() {
                match ProcPool::spawn(regions, ppr, &machine_name, &ProcConfig::default()) {
                    Ok(p) => pool = Some(p),
                    Err(e) => {
                        eprintln!("warning: proc pool {regions}x{ppr} failed to spawn: {e}");
                        return None;
                    }
                }
            }
            let job = ProcJob::SingleV {
                op,
                algo: algo.to_string(),
                counts: counts.as_slice().to_vec(),
                elem_bytes: 8,
            };
            let pl = pool.as_mut().expect("spawned above");
            match pool_median_wall(pl, &job, PROC_WARMUP, proc_iters) {
                Ok(w) => Some(w),
                Err(e) => {
                    eprintln!("warning: proc backend skipped ragged {op}/{algo}: {e}");
                    pool = None;
                    None
                }
            }
        };
        for algo in ["ring", "bruck", "loc-aware", "model-tuned"] {
            let rep = sim::run_allgatherv(algo, &topo, &m, &counts);
            record(BenchRow {
                op: "allgatherv".to_string(),
                algo: algo.to_string(),
                regions,
                ppr,
                p: rep.p,
                n: rep.n,
                vtime: rep.vtime,
                predicted: rep.predicted,
                wall: rep.wall,
                wall_proc: proc_wall(OpKind::Allgatherv, algo),
                verified: rep.verified,
            });
        }
        for algo in ["ring", "loc-aware", "model-tuned"] {
            let rep = sim::run_reduce_scatter_v(algo, &topo, &m, &counts);
            record(BenchRow {
                op: "reduce-scatter-v".to_string(),
                algo: algo.to_string(),
                regions,
                ppr,
                p: rep.p,
                n: rep.n,
                vtime: rep.vtime,
                predicted: rep.predicted,
                wall: rep.wall,
                wall_proc: proc_wall(OpKind::ReduceScatterV, algo),
                verified: rep.verified,
            });
        }
        if let Some(mut p) = pool.take() {
            let _ = p.shutdown();
        }
    }
    // Serving-path rows: the fused zero-copy hot path as perf-trajectory
    // points. `vtime`/`predicted` are the deterministic modeled metrics of
    // the fused serving schedule (K allgathers ⊕ reduce-scatter shards ⊕
    // consensus allreduce) — gated like every other row, so a schedule
    // regression on the serving path fails CI. `wall` is the measured
    // seconds-per-request of a small synthetic `serve_rps` pass (staged
    // copies + serial chunks vs zero-copy views + pipelining) — measured,
    // never gated, and the pair pins the zero-copy win in the artifact.
    {
        use crate::collectives::FuseSpec;
        use crate::coordinator::{serve_rps, RpsConfig, RS_SHARD_ELEMS};
        let (regions, ppr, k, n) = (2usize, 2usize, 4usize, 256usize);
        let topo = Topology::regions(regions, ppr);
        let mut specs: Vec<FuseSpec> =
            (0..k).map(|_| FuseSpec::new(OpKind::Allgather, "loc-bruck", n)).collect();
        specs.push(FuseSpec::new(OpKind::ReduceScatter, "ring", RS_SHARD_ELEMS));
        specs.push(FuseSpec::new(OpKind::Allreduce, "loc-aware", 2 * k));
        let fr = sim::run_fused(&specs, &topo, &m);
        let rcfg = RpsConfig {
            regions,
            ppr,
            requests: 4 * k,
            warmup: k,
            fuse_batch: k,
            rs_shards: 1,
            n_gather: n,
            algo: Algorithm::LocalityBruck,
            consensus: true,
            backend: Backend::Sim,
        };
        let (sec_zc, sec_staged, rps_ok) = match serve_rps(&rcfg) {
            Ok(rep) => (
                1.0 / rep.rps_zero_copy.max(f64::MIN_POSITIVE),
                1.0 / rep.rps_staged.max(f64::MIN_POSITIVE),
                rep.verified,
            ),
            Err(e) => {
                eprintln!("warning: serving_rps measurement failed: {e}");
                (0.0, 0.0, false)
            }
        };
        for (algo, sec) in [("zero-copy", sec_zc), ("staged", sec_staged)] {
            record(BenchRow {
                op: "serving_rps".to_string(),
                algo: algo.to_string(),
                regions,
                ppr,
                p: topo.size(),
                n,
                vtime: fr.fused_vtime,
                predicted: fr.fused_predicted,
                wall: sec,
                wall_proc: None,
                verified: fr.verified && rps_ok,
            });
        }
    }
    let doc = perf_gate::render(m.name, &rows);
    std::fs::write(&path, &doc)?;
    // self-check: the artifact must round-trip through the in-tree parser
    let parsed = perf_gate::parse(&doc)
        .map_err(|e| Error::Precondition(format!("generated bench JSON invalid: {e}")))?;
    if parsed.rows.len() != rows.len() {
        return Err(Error::Precondition(format!(
            "bench JSON round-trip lost rows: {} vs {}",
            parsed.rows.len(),
            rows.len()
        )));
    }
    println!("\nwrote {path} ({} rows)", rows.len());
    if let Some(baseline_path) = args.options.get("compare") {
        let old = std::fs::read_to_string(baseline_path)?;
        let baseline = perf_gate::parse(&old)
            .map_err(|e| Error::Precondition(format!("baseline {baseline_path}: {e}")))?;
        let report = perf_gate::compare_docs(&baseline, &parsed, 0.20)?;
        print!("{}", report.table());
        if !report.passed() {
            eprintln!(
                "perf gate FAILED vs {baseline_path}: {} metric(s) regressed > 20%",
                report.regressions.len()
            );
            return Ok(1);
        }
        println!("perf gate passed vs {baseline_path}");
    }
    Ok(0)
}

/// `locag fit` — measure real per-class α/β over the proc-backend
/// channels (shm ring = local class, Unix socket = non-local) and write a
/// `locag-params-v1` machine file that every `--machine` flag accepts.
pub fn fit(args: &Args) -> Result<i32> {
    use crate::collectives::{model_tuned, schedule::WorldView};

    let quick = args.get_bool("quick");
    let out = args.get_str("out", "results/params_fitted.json");
    let deadline_ms = args.get_usize("deadline-ms", 30_000)?;
    let deadline = std::time::Duration::from_millis(deadline_ms as u64);
    println!(
        "ping-ponging worker-process pairs over each channel class ({} sweep)...",
        if quick { "quick" } else { "full" }
    );
    let report = crate::transport::fit::run_fit(quick, deadline)?;
    // Typed calibration warnings (thin or degenerate protocol segments):
    // the fit is still written, but the flagged lines are underdetermined.
    for w in &report.warnings {
        eprintln!("warning: {w}");
    }
    let classes = [
        ("intra-socket (shm)", &report.machine.intra_socket),
        ("inter-socket (uds)", &report.machine.inter_socket),
        ("inter-node (uds)", &report.machine.inter_node),
    ];
    println!(
        "\n{:<20} {:>12} {:>14} {:>12} {:>14} {:>8}",
        "class", "eager α", "eager β", "rndv α", "rndv β", "cutoff"
    );
    for (label, c) in classes {
        println!(
            "{:<20} {:>12.3e} {:>14.3e} {:>12.3e} {:>14.3e} {:>8}",
            label, c.eager.alpha, c.eager.beta, c.rendezvous.alpha, c.rendezvous.beta,
            c.eager_cutoff
        );
    }
    if let Some(parent) = std::path::Path::new(&out).parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(&out, report.machine.to_json())?;
    println!("\nwrote {out} ({} + {} sample points)", report.shm.len(), report.uds.len());
    // Prove the file is usable end-to-end: load it back through the same
    // path `--machine` takes and let the model-tuned dispatcher pick an
    // allgather against the fitted parameters.
    let loaded = machine_by_name(&out)?;
    let view = WorldView::world(&Topology::regions(2, 4));
    let (winner, _) = model_tuned::pick_allgather(&view, &loaded, 4, 8)?;
    println!(
        "model-tuned check: allgather @ 2x4 on the fitted machine -> {winner}\n\
         use it anywhere: `locag run --algo model-tuned --machine {out}`"
    );
    Ok(0)
}

/// `locag pingpong` — print the per-class postal series.
pub fn pingpong(args: &Args) -> Result<i32> {
    let m = machine_by_name(&args.get_str("machine", "lassen"))?;
    println!("{:<10} {:>14} {:>14} {:>14}", "bytes", "intra-socket", "inter-socket", "inter-node");
    let mut sz = 1usize;
    while sz <= 1 << 20 {
        print!("{sz:<10}");
        for class in Locality::ALL {
            print!(" {:>14}", seconds(m.cost(class, sz)));
        }
        println!();
        sz *= 4;
    }
    Ok(0)
}

/// `locag e2e` — the serving pipeline (needs `make artifacts`).
/// `--collective-backend proc` runs the fused collective hot path on a
/// persistent multi-process worker pool instead of thread mailboxes.
pub fn e2e(args: &Args) -> Result<i32> {
    use crate::transport::Backend;

    if args.get_bool("measure-rps") {
        return e2e_rps(args);
    }
    let cfg = ServeConfig {
        artifact_dir: args.get_str("artifacts", "artifacts").into(),
        algo: algo_by_name(&args.get_str("algo", "model-tuned"))?,
        regions: args.get_usize("regions", 2)?,
        requests: args.get_usize("requests", 16)?,
        warmup: args.get_usize("warmup", 2)?,
        check: !args.get_bool("no-check"),
        fused: args.get_bool("fused"),
        consensus: !args.get_bool("no-consensus"),
        fuse_batch: args.get_usize("fuse-batch", 1)?.max(1),
        collective_backend: Backend::parse_or_err(&args.get_str("collective-backend", "sim"))?,
        staged: args.get_bool("staged"),
        pipeline: !args.get_bool("no-pipeline"),
        rs_shards: args.get_usize("rs-shards", 0)?,
    };
    println!(
        "serving via PJRT: allgather={}, {} regions, {} requests, fuse-batch {}, \
         rs-shards {}{}{}{}{}",
        cfg.algo,
        cfg.regions,
        cfg.requests,
        cfg.fuse_batch,
        cfg.rs_shards,
        if cfg.fused { ", fused final" } else { "" },
        if cfg.staged { ", staged copies" } else { ", zero-copy views" },
        if cfg.pipeline { ", pipelined" } else { ", serial chunks" },
        if cfg.collective_backend == Backend::Proc { ", proc collectives" } else { "" }
    );
    let rep = serve(&cfg)?;
    println!(
        "model: tp={} params={} | verified={} (max err {:.2e})",
        rep.tp, rep.params, rep.verified, rep.max_err
    );
    print!("{}", rep.metrics.table());
    print!("{}", rep.trace.table());
    println!("output sample: {:?}", rep.output_sample);
    Ok(if rep.verified { 0 } else { 1 })
}

/// `locag e2e --measure-rps` — synthetic serving-throughput measurement.
/// Needs no artifacts: the PJRT stages are replaced by a deterministic
/// generator/verifier load, so the measurement isolates the collective
/// hot path. Runs the same heavy request stream twice — staged copies +
/// serial chunks, then zero-copy views + cross-chunk pipelining — and
/// reports requests/sec for both plus the speedup.
fn e2e_rps(args: &Args) -> Result<i32> {
    use crate::coordinator::{serve_rps, RpsConfig};
    use crate::transport::Backend;

    let cfg = RpsConfig {
        regions: args.get_usize("regions", 2)?,
        ppr: args.get_usize("ppr", 2)?,
        requests: args.get_usize("requests", 64)?,
        warmup: args.get_usize("warmup", 8)?,
        fuse_batch: args.get_usize("fuse-batch", 4)?.max(1),
        rs_shards: args.get_usize("rs-shards", 2)?,
        n_gather: args.get_usize("values", 4096)?,
        algo: algo_by_name(&args.get_str("algo", "model-tuned"))?,
        consensus: !args.get_bool("no-consensus"),
        backend: Backend::parse_or_err(&args.get_str("collective-backend", "sim"))?,
    };
    println!(
        "serving throughput (synthetic load, no artifacts): {} ranks ({} regions x {}), \
         {} requests (+{} warmup), fuse-batch {}, {} gather elems/req, {} rs shards, \
         {} backend",
        cfg.regions * cfg.ppr,
        cfg.regions,
        cfg.ppr,
        cfg.requests,
        cfg.warmup,
        cfg.fuse_batch,
        cfg.n_gather,
        cfg.rs_shards,
        if cfg.backend == Backend::Proc { "proc" } else { "sim" }
    );
    let rep = serve_rps(&cfg)?;
    println!("  staged copies + serial chunks:  {:>10.1} req/s", rep.rps_staged);
    println!("  zero-copy views + pipelining:   {:>10.1} req/s", rep.rps_zero_copy);
    println!(
        "  speedup {:.2}x over {} chunks | verified={}",
        rep.speedup, rep.chunks, rep.verified
    );
    Ok(if rep.verified { 0 } else { 1 })
}

/// `locag pattern` — print the step-by-step communication pattern of an
/// algorithm (the paper's Figures 1 and 4 as text).
pub fn pattern(args: &Args) -> Result<i32> {
    use crate::collectives;
    use crate::comm::{CommWorld, Timing};
    let algo = algo_by_name(&args.get_str("algo", "loc-bruck"))?;
    let regions = args.get_usize("regions", 4)?;
    let ppr = args.get_usize("ppr", 4)?;
    let n = args.get_usize("values", 1)?;
    let topo = Topology::regions(regions, ppr);
    let m = machine_by_name(&args.get_str("machine", "lassen"))?;
    println!(
        "{} on {} ranks ({regions} regions x {ppr}), {n} u32 value(s)/rank:\n",
        algo.name(),
        topo.size()
    );
    let run = CommWorld::run_traced(&topo, Timing::Virtual(m), |c| {
        let mine: Vec<u32> = (0..n).map(|j| (c.rank() * n + j) as u32).collect();
        collectives::allgather(algo, c, &mine).map(|v| v.len())
    });
    for (rank, r) in run.results.iter().enumerate() {
        if let Err(e) = r {
            eprintln!("rank {rank}: {e}");
            return Ok(1);
        }
    }
    print!("{}", crate::trace::render_steps(&run.events));
    println!();
    print!("{}", run.trace.table());
    Ok(0)
}

/// `locag validate` — self-check across algorithms and shapes.
pub fn validate(args: &Args) -> Result<i32> {
    let max_p = args.get_usize("max-p", 256)?;
    let m = MachineParams::lassen();
    let mut failures = 0usize;
    let shapes: Vec<(usize, usize)> = vec![
        (1, 4),
        (2, 2),
        (4, 4),
        (6, 4),
        (8, 2),
        (16, 4),
        (5, 8),
        (32, 8),
    ];
    for (regions, ppr) in shapes {
        if regions * ppr > max_p {
            continue;
        }
        let topo = Topology::regions(regions, ppr);
        for algo in Algorithm::ALL {
            if algo == Algorithm::RecursiveDoubling && !topo.size().is_power_of_two() {
                continue; // documented precondition
            }
            let rep = sim::run_allgather(algo, &topo, &m, 2);
            let ok = rep.verified;
            // paper bounds on the contribution
            let bound_ok = match algo {
                Algorithm::LocalityBruck => {
                    let expect = crate::util::ilog_ceil(ppr.max(2), regions) as u64;
                    rep.trace.max_nonlocal_msgs() <= expect.max(1)
                }
                Algorithm::Bruck => {
                    rep.trace.max_nonlocal_msgs()
                        <= crate::util::ilog2_ceil(topo.size()) as u64
                }
                _ => true,
            };
            if !ok || !bound_ok {
                failures += 1;
                println!(
                    "FAIL {algo} @ {regions}x{ppr}: verified={ok} bound_ok={bound_ok} {:?}",
                    rep.errors
                );
            } else {
                println!(
                    "ok   {:<18} @ {:>4} ranks ({regions} regions x {ppr}): {} | maxNL {}",
                    algo.name(),
                    topo.size(),
                    seconds(rep.vtime),
                    rep.trace.max_nonlocal_msgs()
                );
            }
        }
    }
    if failures > 0 {
        println!("{failures} failures");
        return Ok(1);
    }
    println!("all algorithms validated");
    Ok(0)
}
