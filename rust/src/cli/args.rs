//! Tiny `--key value` argument parser.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed command line: positionals + `--key value` options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
}

impl Args {
    /// Parse argv (without the program name). A `--flag` followed by
    /// another option or end-of-args is treated as boolean `"true"`.
    pub fn parse(argv: Vec<String>) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                let val = argv.get(i + 1);
                match val {
                    Some(v) if !v.starts_with("--") => {
                        out.options.insert(key.to_string(), v.clone());
                        i += 2;
                    }
                    _ => {
                        out.options.insert(key.to_string(), "true".to_string());
                        i += 1;
                    }
                }
            } else {
                out.positional.push(a.clone());
                i += 1;
            }
        }
        out
    }

    /// String option with default.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.options.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Required/parseable usize option with default.
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                Error::Precondition(format!("--{key} expects an integer, got '{v}'"))
            }),
        }
    }

    /// Boolean flag.
    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.options.get(key).map(String::as_str), Some("true") | Some("1"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()).collect())
    }

    #[test]
    fn positionals_and_options() {
        let a = parse(&["figure", "9", "--out", "x.csv", "--max-p", "64"]);
        assert_eq!(a.positional, vec!["figure", "9"]);
        assert_eq!(a.get_str("out", ""), "x.csv");
        assert_eq!(a.get_usize("max-p", 0).unwrap(), 64);
    }

    #[test]
    fn boolean_flags() {
        let a = parse(&["e2e", "--check", "--algo", "bruck"]);
        assert!(a.get_bool("check"));
        assert_eq!(a.get_str("algo", ""), "bruck");
        assert!(!a.get_bool("missing"));
    }

    #[test]
    fn bad_integer_is_error() {
        let a = parse(&["x", "--n", "abc"]);
        assert!(a.get_usize("n", 1).is_err());
        assert_eq!(a.get_usize("other", 7).unwrap(), 7);
    }
}
