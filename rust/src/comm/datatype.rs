//! Plain-old-data element types and byte conversion — the crate's analogue
//! of MPI datatypes.
//!
//! The wire format of the mini-MPI is a byte vector; collectives are generic
//! over any [`Pod`] element type. Conversion uses raw-pointer copies (the
//! hot path of every collective), which is sound because `Pod` types have no
//! padding, no invalid bit patterns and no drop glue.

/// Marker for types that can be transmuted to/from bytes.
///
/// # Safety
/// Implementors must be `Copy`, have no padding bytes, and accept any bit
/// pattern as a valid value (all primitive integer/float types qualify).
pub unsafe trait Pod: Copy + Send + Sync + Default + PartialEq + std::fmt::Debug + 'static {}

unsafe impl Pod for u8 {}
unsafe impl Pod for i8 {}
unsafe impl Pod for u16 {}
unsafe impl Pod for i16 {}
unsafe impl Pod for u32 {}
unsafe impl Pod for i32 {}
unsafe impl Pod for u64 {}
unsafe impl Pod for i64 {}
unsafe impl Pod for f32 {}
unsafe impl Pod for f64 {}
unsafe impl Pod for usize {}

/// Serialize a slice of `Pod` elements into a fresh byte vector.
pub fn to_bytes<T: Pod>(xs: &[T]) -> Vec<u8> {
    let n = std::mem::size_of_val(xs);
    let mut out = Vec::with_capacity(n);
    // SAFETY: `T: Pod` has no padding; reading `n` bytes from the slice's
    // base pointer is reading fully-initialized memory.
    unsafe {
        std::ptr::copy_nonoverlapping(xs.as_ptr() as *const u8, out.as_mut_ptr(), n);
        out.set_len(n);
    }
    out
}

/// Deserialize bytes into a vector of `Pod` elements.
///
/// Returns `None` if `bytes.len()` is not a multiple of `size_of::<T>()`.
pub fn from_bytes<T: Pod>(bytes: &[u8]) -> Option<Vec<T>> {
    let esz = std::mem::size_of::<T>();
    if esz == 0 || bytes.len() % esz != 0 {
        return None;
    }
    let n = bytes.len() / esz;
    let mut out: Vec<T> = Vec::with_capacity(n);
    // SAFETY: any bit pattern is a valid `T` (Pod contract); the source has
    // exactly `n * esz` initialized bytes.
    unsafe {
        std::ptr::copy_nonoverlapping(bytes.as_ptr(), out.as_mut_ptr() as *mut u8, n * esz);
        out.set_len(n);
    }
    Some(out)
}

/// Serialize a slice of `Pod` elements into an existing byte buffer
/// (zero-allocation send-side packing, the inverse of [`copy_into`]).
///
/// Returns `false` (and copies nothing) on length mismatch.
pub fn write_bytes<T: Pod>(xs: &[T], dst: &mut [u8]) -> bool {
    let n = std::mem::size_of_val(xs);
    if dst.len() != n {
        return false;
    }
    // SAFETY: same as `to_bytes`, but into caller-provided storage.
    unsafe {
        std::ptr::copy_nonoverlapping(xs.as_ptr() as *const u8, dst.as_mut_ptr(), n);
    }
    true
}

/// Reinterpret a `Pod` slice as its underlying bytes — the zero-copy
/// sibling of [`to_bytes`]/[`write_bytes`]. No copy happens: the returned
/// slice aliases `xs`, which is what lets segmented buffer views
/// ([`crate::collectives::schedule::IoView`]) hand caller-owned typed
/// buffers straight to the byte-level schedule interpreter.
pub fn as_bytes<T: Pod>(xs: &[T]) -> &[u8] {
    // SAFETY: `T: Pod` has no padding and no uninitialized bytes; `u8` has
    // alignment 1, so any `T` pointer is a valid `u8` pointer.
    unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, std::mem::size_of_val(xs)) }
}

/// Mutable byte reinterpretation of a `Pod` slice (zero-copy sibling of
/// [`copy_into`]). Writing any bit pattern through the result is sound
/// because every bit pattern is a valid `T` (the `Pod` contract).
pub fn as_bytes_mut<T: Pod>(xs: &mut [T]) -> &mut [u8] {
    let n = std::mem::size_of_val(xs);
    // SAFETY: as for `as_bytes`; exclusivity is inherited from `&mut xs`.
    unsafe { std::slice::from_raw_parts_mut(xs.as_mut_ptr() as *mut u8, n) }
}

/// Copy bytes into an existing element slice (zero-allocation receive path).
///
/// Returns `false` (and copies nothing) on length mismatch.
pub fn copy_into<T: Pod>(bytes: &[u8], dst: &mut [T]) -> bool {
    if bytes.len() != std::mem::size_of_val(dst) {
        return false;
    }
    // SAFETY: same as `from_bytes`, but into caller-provided storage.
    unsafe {
        std::ptr::copy_nonoverlapping(bytes.as_ptr(), dst.as_mut_ptr() as *mut u8, bytes.len());
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_u32() {
        let xs: Vec<u32> = vec![0, 1, 0xDEAD_BEEF, u32::MAX];
        let b = to_bytes(&xs);
        assert_eq!(b.len(), 16);
        let back: Vec<u32> = from_bytes(&b).unwrap();
        assert_eq!(back, xs);
    }

    #[test]
    fn roundtrip_f64() {
        let xs = vec![0.0f64, -1.5, f64::MAX, f64::MIN_POSITIVE];
        let back: Vec<f64> = from_bytes(&to_bytes(&xs)).unwrap();
        assert_eq!(back, xs);
    }

    #[test]
    fn roundtrip_empty() {
        let xs: Vec<u64> = vec![];
        let b = to_bytes(&xs);
        assert!(b.is_empty());
        assert_eq!(from_bytes::<u64>(&b).unwrap(), xs);
    }

    #[test]
    fn misaligned_length_rejected() {
        let b = vec![1u8, 2, 3];
        assert!(from_bytes::<u32>(&b).is_none());
        assert!(from_bytes::<u16>(&b).is_none());
        assert!(from_bytes::<u8>(&b).is_some());
    }

    #[test]
    fn write_bytes_roundtrips_and_checks_length() {
        let xs: Vec<u32> = vec![7, 8, 9];
        let mut buf = vec![0u8; 12];
        assert!(write_bytes(&xs, &mut buf));
        assert_eq!(from_bytes::<u32>(&buf).unwrap(), xs);
        let mut wrong = vec![0u8; 11];
        assert!(!write_bytes(&xs, &mut wrong));
    }

    #[test]
    fn byte_views_alias_without_copy() {
        let xs: Vec<u32> = vec![7, 8, 9];
        assert_eq!(as_bytes(&xs), to_bytes(&xs).as_slice());
        let mut ys = [0u32; 2];
        as_bytes_mut(&mut ys).copy_from_slice(&to_bytes(&[5u32, 6]));
        assert_eq!(ys, [5, 6]);
        // empty slices are fine
        let empty: &[u64] = &[];
        assert!(as_bytes(empty).is_empty());
    }

    #[test]
    fn copy_into_checks_length() {
        let xs: Vec<u32> = vec![7, 8, 9];
        let b = to_bytes(&xs);
        let mut dst = [0u32; 3];
        assert!(copy_into(&b, &mut dst));
        assert_eq!(dst, [7, 8, 9]);
        let mut wrong = [0u32; 2];
        assert!(!copy_into(&b, &mut wrong));
        assert_eq!(wrong, [0, 0]);
    }
}
