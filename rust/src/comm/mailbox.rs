//! Tagged mailboxes — the matching engine of the mini-MPI.
//!
//! Every world rank owns one [`Mailbox`]. A send deposits a [`Message`]
//! into the destination's mailbox; a receive blocks until a message
//! matching `(context, source, tag)` is present and removes it. Messages
//! between the same (source, context, tag) triple are matched in FIFO
//! order, mirroring MPI's non-overtaking guarantee.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// One in-flight message.
#[derive(Debug)]
pub struct Message {
    /// Sender's world rank.
    pub src: usize,
    /// Communicator context id (distinguishes split communicators).
    pub ctx: u64,
    /// User/collective tag.
    pub tag: u64,
    /// Payload.
    pub bytes: Vec<u8>,
    /// Virtual arrival time (0.0 under wall-clock timing).
    pub stamp: f64,
}

/// Match selector for receives.
#[derive(Debug, Clone, Copy)]
pub struct Pattern {
    pub src: Option<usize>,
    pub ctx: u64,
    pub tag: u64,
}

impl Pattern {
    fn matches(&self, m: &Message) -> bool {
        m.ctx == self.ctx && m.tag == self.tag && self.src.map_or(true, |s| s == m.src)
    }
}

/// How long a blocking receive waits before declaring the peer lost.
pub const RECV_TIMEOUT: Duration = Duration::from_secs(60);

/// A rank's inbound queue with condition-variable wakeups.
#[derive(Debug, Default)]
pub struct Mailbox {
    inner: Mutex<VecDeque<Message>>,
    cv: Condvar,
}

impl Mailbox {
    pub fn new() -> Mailbox {
        Mailbox::default()
    }

    /// Deposit a message and wake any waiting receiver.
    pub fn push(&self, msg: Message) {
        let mut q = self.inner.lock().expect("mailbox poisoned");
        q.push_back(msg);
        // Receivers match on (ctx, src, tag); any of them might want this.
        self.cv.notify_all();
    }

    /// Take the first message matching `pat`, if one is queued.
    pub fn try_take(&self, pat: Pattern) -> Option<Message> {
        let mut q = self.inner.lock().expect("mailbox poisoned");
        Self::take_locked(&mut q, pat)
    }

    fn take_locked(q: &mut VecDeque<Message>, pat: Pattern) -> Option<Message> {
        let idx = q.iter().position(|m| pat.matches(m))?;
        q.remove(idx)
    }

    /// Block until a matching message arrives, then remove and return it.
    ///
    /// Returns `None` only on timeout ([`RECV_TIMEOUT`]), which the comm
    /// layer reports as a peer-disconnect error rather than hanging the
    /// whole test suite on a deadlocked algorithm.
    pub fn take_blocking(&self, pat: Pattern) -> Option<Message> {
        let mut q = self.inner.lock().expect("mailbox poisoned");
        loop {
            if let Some(m) = Self::take_locked(&mut q, pat) {
                return Some(m);
            }
            let (guard, res) = self
                .cv
                .wait_timeout(q, RECV_TIMEOUT)
                .expect("mailbox poisoned");
            q = guard;
            if res.timed_out() && !q.iter().any(|m| pat.matches(m)) {
                return None;
            }
        }
    }

    /// Number of queued messages (for tests/diagnostics).
    pub fn len(&self) -> usize {
        self.inner.lock().expect("mailbox poisoned").len()
    }

    /// True if no messages are queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn msg(src: usize, ctx: u64, tag: u64, byte: u8) -> Message {
        Message { src, ctx, tag, bytes: vec![byte], stamp: 0.0 }
    }

    #[test]
    fn fifo_within_matching_triple() {
        let mb = Mailbox::new();
        mb.push(msg(1, 0, 7, 10));
        mb.push(msg(1, 0, 7, 20));
        let pat = Pattern { src: Some(1), ctx: 0, tag: 7 };
        assert_eq!(mb.try_take(pat).unwrap().bytes, vec![10]);
        assert_eq!(mb.try_take(pat).unwrap().bytes, vec![20]);
        assert!(mb.try_take(pat).is_none());
    }

    #[test]
    fn matching_respects_ctx_src_tag() {
        let mb = Mailbox::new();
        mb.push(msg(1, 0, 7, 1));
        mb.push(msg(2, 0, 7, 2));
        mb.push(msg(1, 9, 7, 3));
        mb.push(msg(1, 0, 8, 4));
        // wrong tag / ctx / src never match
        assert!(mb.try_take(Pattern { src: Some(3), ctx: 0, tag: 7 }).is_none());
        assert!(mb.try_take(Pattern { src: Some(1), ctx: 1, tag: 7 }).is_none());
        // exact matches pull the right messages out of order
        assert_eq!(
            mb.try_take(Pattern { src: Some(1), ctx: 9, tag: 7 }).unwrap().bytes,
            vec![3]
        );
        assert_eq!(
            mb.try_take(Pattern { src: Some(2), ctx: 0, tag: 7 }).unwrap().bytes,
            vec![2]
        );
        assert_eq!(mb.len(), 2);
    }

    #[test]
    fn wildcard_source_matches_first() {
        let mb = Mailbox::new();
        mb.push(msg(5, 0, 1, 50));
        mb.push(msg(6, 0, 1, 60));
        let m = mb.try_take(Pattern { src: None, ctx: 0, tag: 1 }).unwrap();
        assert_eq!(m.src, 5);
    }

    #[test]
    fn blocking_take_wakes_on_push() {
        let mb = Arc::new(Mailbox::new());
        let mb2 = mb.clone();
        let h = std::thread::spawn(move || {
            mb2.take_blocking(Pattern { src: Some(0), ctx: 0, tag: 42 })
                .map(|m| m.bytes[0])
        });
        std::thread::sleep(Duration::from_millis(20));
        mb.push(msg(0, 0, 42, 99));
        assert_eq!(h.join().unwrap(), Some(99));
    }
}
