//! The mini-MPI substrate: communicators over thread mailboxes with a
//! locality-aware virtual-clock transport.
//!
//! The paper's algorithms are MPI programs built from `MPI_Isend`,
//! `MPI_Irecv`, `MPI_Waitall` and `MPI_Comm_split`. This module provides
//! those semantics inside one process: each *world rank* is an OS thread
//! owning a tagged [`mailbox::Mailbox`]; a [`Comm`] handle exposes rank,
//! size, point-to-point operations and sub-communicator construction.
//!
//! ## Timing modes
//!
//! * [`Timing::Virtual`] — every charged send advances the sender's
//!   **virtual clock** by `α_c + β_c·bytes` for the locality class `c` of
//!   the (src, dst) pair (paper Eq. 2) and stamps the message with the
//!   post-charge time; a receive advances the receiver's clock to
//!   `max(own, stamp)`. Per-rank clocks after a collective reproduce the
//!   paper's per-process postal costs over the *real* message schedule —
//!   deterministically, with no wall-clock noise.
//! * [`Timing::Wallclock`] — clocks are untouched; callers measure real
//!   elapsed time around collective calls (used by the perf pass).
//!
//! Communicator construction ([`Comm::sub`], [`Comm::split_regions`]) is
//! deterministic from globally-known topology, so it needs no exchange and
//! is never charged — matching the paper's setup, where communicators are
//! created once outside the timed region.

pub mod datatype;
pub mod mailbox;

pub use datatype::{as_bytes, as_bytes_mut, copy_into, from_bytes, to_bytes, write_bytes, Pod};

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::model::MachineParams;
use crate::topology::Topology;
use crate::trace::{RankTrace, TraceSummary};
use mailbox::{Mailbox, Message, Pattern};

/// First tag reserved for internal collective traffic; user tags must be
/// below this value.
pub const COLL_TAG_BASE: u64 = 1 << 32;

/// Global count of sub-communicator constructions ([`Comm::sub`]).
///
/// Pure diagnostics: the persistent-plan tests assert that repeated
/// [`crate::collectives::AllgatherPlan::execute`] calls build **zero** new
/// sub-communicators (all groups are derived once at plan time).
static SUB_COMMS_BUILT: AtomicU64 = AtomicU64::new(0);

/// Number of sub-communicators constructed process-wide since start.
pub fn sub_comms_built() -> u64 {
    SUB_COMMS_BUILT.load(Ordering::Relaxed)
}

/// Transport timing mode.
#[derive(Debug, Clone)]
pub enum Timing {
    /// Locality-aware postal model (paper Eq. 2) on a virtual clock.
    Virtual(MachineParams),
    /// No modeled time; callers take wall-clock measurements themselves.
    Wallclock,
}

/// Per-rank mutable state (clock + trace). The clock is an `AtomicU64`
/// holding `f64` bits: only the owning thread writes it during a run, other
/// threads read it only at quiescent points (barriers / after join).
struct RankState {
    clock: AtomicU64,
    trace: Mutex<RankTrace>,
}

impl RankState {
    fn new() -> RankState {
        RankState {
            clock: AtomicU64::new(0f64.to_bits()),
            trace: Mutex::new(RankTrace::default()),
        }
    }

    fn clock(&self) -> f64 {
        f64::from_bits(self.clock.load(Ordering::Relaxed))
    }

    fn set_clock(&self, t: f64) {
        self.clock.store(t.to_bits(), Ordering::Relaxed);
    }
}

/// State shared by all ranks of a world.
struct WorldShared {
    topo: Topology,
    timing: Timing,
    mailboxes: Vec<Mailbox>,
    states: Vec<RankState>,
    /// Opt-in per-message event log (`run_traced`); drives `locag pattern`.
    events: Option<Mutex<Vec<crate::trace::MsgEvent>>>,
}

/// A communicator handle owned by one rank thread.
///
/// Not `Sync`: a `Comm` lives on the thread that owns its rank, exactly
/// like an MPI communicator is used from one process.
pub struct Comm {
    /// World rank of the owning thread.
    world_rank: usize,
    /// Rank within this communicator.
    rank: usize,
    /// Communicator rank -> world rank.
    ranks: Arc<Vec<usize>>,
    /// Context id for message matching.
    ctx: u64,
    /// Per-communicator operation sequence (collective tags, sub-comm ids).
    seq: Cell<u64>,
    world: Arc<WorldShared>,
}

/// Result of running a world: per-rank closure results, final virtual
/// clocks and the aggregated send trace.
#[derive(Debug)]
pub struct WorldRun<R> {
    pub results: Vec<R>,
    pub vtimes: Vec<f64>,
    pub trace: TraceSummary,
    /// Per-message events (only populated by [`CommWorld::run_traced`]).
    pub events: Vec<crate::trace::MsgEvent>,
}

impl<R> WorldRun<R> {
    /// Max final virtual clock over ranks — the modeled completion time.
    pub fn max_vtime(&self) -> f64 {
        self.vtimes.iter().copied().fold(0.0, f64::max)
    }
}

/// Namespace for world construction (re-exported in the prelude).
pub struct CommWorld;

impl CommWorld {
    /// Spawn one thread per rank of `topo`, hand each a world [`Comm`], run
    /// `f`, join, and collect results + clocks + traces.
    ///
    /// Panics in `f` are propagated after all threads are joined.
    pub fn run<R, F>(topo: &Topology, timing: Timing, f: F) -> WorldRun<R>
    where
        R: Send,
        F: Fn(&mut Comm) -> R + Sync,
    {
        Self::run_inner(topo, timing, false, f)
    }

    /// Like [`CommWorld::run`] but additionally records every charged
    /// message as a [`crate::trace::MsgEvent`] (the paper's step-by-step
    /// communication-pattern figures).
    pub fn run_traced<R, F>(topo: &Topology, timing: Timing, f: F) -> WorldRun<R>
    where
        R: Send,
        F: Fn(&mut Comm) -> R + Sync,
    {
        Self::run_inner(topo, timing, true, f)
    }

    fn run_inner<R, F>(topo: &Topology, timing: Timing, traced: bool, f: F) -> WorldRun<R>
    where
        R: Send,
        F: Fn(&mut Comm) -> R + Sync,
    {
        let size = topo.size();
        let shared = Arc::new(WorldShared {
            topo: topo.clone(),
            timing,
            mailboxes: (0..size).map(|_| Mailbox::new()).collect(),
            states: (0..size).map(|_| RankState::new()).collect(),
            events: traced.then(|| Mutex::new(Vec::new())),
        });
        let ranks: Arc<Vec<usize>> = Arc::new((0..size).collect());
        let mut results: Vec<Option<R>> = (0..size).map(|_| None).collect();

        std::thread::scope(|scope| {
            let handles: Vec<_> = results
                .iter_mut()
                .enumerate()
                .map(|(r, slot)| {
                    let shared = shared.clone();
                    let ranks = ranks.clone();
                    let f = &f;
                    scope.spawn(move || {
                        let mut comm = Comm {
                            world_rank: r,
                            rank: r,
                            ranks,
                            ctx: 0,
                            seq: Cell::new(0),
                            world: shared,
                        };
                        *slot = Some(f(&mut comm));
                    })
                })
                .collect();
            for h in handles {
                if let Err(e) = h.join() {
                    std::panic::resume_unwind(e);
                }
            }
        });

        let vtimes = shared.states.iter().map(|s| s.clock()).collect();
        let trace = TraceSummary::new(
            shared
                .states
                .iter()
                .map(|s| s.trace.lock().expect("trace poisoned").clone())
                .collect(),
        );
        let events = shared
            .events
            .as_ref()
            .map(|m| m.lock().expect("events poisoned").clone())
            .unwrap_or_default();
        WorldRun {
            results: results.into_iter().map(|r| r.expect("rank produced no result")).collect(),
            vtimes,
            trace,
            events,
        }
    }
}

impl Comm {
    /// Rank within this communicator.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Number of ranks in this communicator.
    pub fn size(&self) -> usize {
        self.ranks.len()
    }

    /// World rank of the calling thread.
    pub fn world_rank(&self) -> usize {
        self.world_rank
    }

    /// World rank of communicator rank `r`.
    pub fn world_rank_of(&self, r: usize) -> usize {
        self.ranks[r]
    }

    /// The world topology.
    pub fn topology(&self) -> &Topology {
        &self.world.topo
    }

    /// Machine parameters when running under virtual timing.
    pub fn machine(&self) -> Option<&MachineParams> {
        match &self.world.timing {
            Timing::Virtual(m) => Some(m),
            Timing::Wallclock => None,
        }
    }

    /// Current virtual clock of this rank (seconds).
    pub fn clock(&self) -> f64 {
        self.state().clock()
    }

    /// Overwrite this rank's virtual clock.
    pub fn set_clock(&self, t: f64) {
        self.state().set_clock(t);
    }

    fn state(&self) -> &RankState {
        &self.world.states[self.world_rank]
    }

    /// Snapshot of this rank's send trace.
    pub fn trace_snapshot(&self) -> RankTrace {
        self.state().trace.lock().expect("trace poisoned").clone()
    }

    fn check_rank(&self, r: usize, during: &'static str) -> Result<()> {
        if r >= self.size() {
            return Err(Error::RankOutOfRange { rank: r, size: self.size() });
        }
        let _ = during;
        Ok(())
    }

    // ------------------------------------------------------------------
    // point-to-point
    // ------------------------------------------------------------------

    fn post(&self, dst: usize, tag: u64, bytes: Vec<u8>, charge: bool) -> Result<()> {
        self.check_rank(dst, "send")?;
        let src_w = self.world_rank;
        let dst_w = self.ranks[dst];
        let mut stamp = 0.0;
        // Self-sends are a local memcpy in any real MPI: never charged.
        let charge = charge && src_w != dst_w;
        if charge {
            let topo = &self.world.topo;
            let class = topo.classify(src_w, dst_w);
            let is_local = topo.is_local(src_w, dst_w);
            if let Timing::Virtual(m) = &self.world.timing {
                let cost = m.cost(class, bytes.len());
                let t = self.state().clock() + cost;
                self.state().set_clock(t);
                stamp = t;
            }
            self.state()
                .trace
                .lock()
                .expect("trace poisoned")
                .record(class, is_local, bytes.len());
            if let Some(events) = &self.world.events {
                events.lock().expect("events poisoned").push(crate::trace::MsgEvent {
                    src: src_w,
                    dst: dst_w,
                    tag,
                    bytes: bytes.len(),
                    class,
                    region_local: is_local,
                    vtime: stamp,
                });
            }
        } else if let Timing::Virtual(_) = &self.world.timing {
            // Uncharged control message still carries the clock so barriers
            // can propagate maxima.
            stamp = self.state().clock();
        }
        self.world.mailboxes[dst_w].push(Message {
            src: src_w,
            ctx: self.ctx,
            tag,
            bytes,
            stamp,
        });
        Ok(())
    }

    fn take(&self, src: Option<usize>, tag: u64, sync_clock: bool) -> Result<Message> {
        if let Some(s) = src {
            self.check_rank(s, "recv")?;
        }
        let pat = Pattern {
            src: src.map(|s| self.ranks[s]),
            ctx: self.ctx,
            tag,
        };
        let msg = self.world.mailboxes[self.world_rank]
            .take_blocking(pat)
            .ok_or(Error::Disconnected {
                rank: src.unwrap_or(usize::MAX),
                during: "recv",
            })?;
        if sync_clock {
            if let Timing::Virtual(_) = &self.world.timing {
                let t = self.state().clock().max(msg.stamp);
                self.state().set_clock(t);
            }
        }
        Ok(msg)
    }

    /// Blocking (buffered) send of a typed slice to communicator rank `dst`.
    pub fn send<T: Pod>(&self, buf: &[T], dst: usize, tag: u64) -> Result<()> {
        self.post(dst, tag, to_bytes(buf), true)
    }

    /// Blocking receive from communicator rank `src`; returns the payload.
    pub fn recv<T: Pod>(&self, src: usize, tag: u64) -> Result<Vec<T>> {
        let msg = self.take(Some(src), tag, true)?;
        from_bytes(&msg.bytes).ok_or(Error::DatatypeMismatch {
            bytes: msg.bytes.len(),
            elem_size: std::mem::size_of::<T>(),
        })
    }

    /// Blocking receive into a caller-provided buffer (must match exactly).
    pub fn recv_into<T: Pod>(&self, src: usize, tag: u64, dst: &mut [T]) -> Result<()> {
        let msg = self.take(Some(src), tag, true)?;
        if !copy_into(&msg.bytes, dst) {
            return Err(Error::SizeMismatch {
                expected: std::mem::size_of_val(dst),
                got: msg.bytes.len(),
            });
        }
        Ok(())
    }

    /// Non-blocking send. The mini-MPI buffers eagerly, so the request is
    /// complete on return; the call still exists so algorithm code reads
    /// like its MPI original.
    pub fn isend<T: Pod>(&self, buf: &[T], dst: usize, tag: u64) -> Result<SendReq> {
        self.send(buf, dst, tag)?;
        Ok(SendReq { _completed: true })
    }

    /// Non-blocking receive: returns a request to [`RecvReq::wait`] on.
    pub fn irecv(&self, src: usize, tag: u64) -> RecvReq {
        RecvReq { src, tag }
    }

    /// Combined send+receive (deadlock-free thanks to buffered sends).
    pub fn sendrecv<T: Pod>(
        &self,
        sendbuf: &[T],
        dst: usize,
        src: usize,
        tag: u64,
    ) -> Result<Vec<T>> {
        self.send(sendbuf, dst, tag)?;
        self.recv(src, tag)
    }

    /// Allocate a fresh internal tag for one collective operation. All
    /// ranks of a communicator call collectives in the same order, so the
    /// per-comm sequence agrees across ranks.
    pub fn next_coll_tag(&self) -> u64 {
        self.reserve_coll_tags(1)
    }

    /// Reserve a block of `count` consecutive collective tags and return
    /// the first. This is how persistent plans pre-allocate their whole tag
    /// schedule at plan time, so that `execute` consumes **no** tags.
    ///
    /// Collective in the MPI sense: every rank of the communicator must
    /// reserve the same counts in the same order (plan construction is a
    /// collective call, exactly like `MPI_Allgather_init`).
    pub fn reserve_coll_tags(&self, count: u64) -> u64 {
        let s = self.seq.get();
        self.seq.set(s + count);
        COLL_TAG_BASE + s
    }

    /// Duplicate this communicator handle for retention inside a persistent
    /// collective plan.
    ///
    /// The clone shares the context id, so messages sent through it match
    /// receives posted on the original (and vice versa) — like holding a
    /// second reference to an MPI communicator rather than `MPI_Comm_dup`.
    /// A retained handle must only be used with tags reserved via
    /// [`Comm::reserve_coll_tags`] on the originating handle; calling
    /// [`Comm::next_coll_tag`] on the clone would desynchronize the two
    /// sequence counters.
    pub fn retain(&self) -> Comm {
        Comm {
            world_rank: self.world_rank,
            rank: self.rank,
            ranks: self.ranks.clone(),
            ctx: self.ctx,
            seq: Cell::new(self.seq.get()),
            world: self.world.clone(),
        }
    }

    // ------------------------------------------------------------------
    // communicator construction
    // ------------------------------------------------------------------

    /// Build a sub-communicator from communicator ranks `members` (must be
    /// sorted, unique and include the caller; every member must pass the
    /// identical list). Deterministic — no communication, no time charged.
    pub fn sub(&self, members: &[usize]) -> Result<Comm> {
        if !members.windows(2).all(|w| w[0] < w[1]) {
            return Err(Error::Precondition(
                "sub(): member list must be sorted and unique".into(),
            ));
        }
        let my = members
            .iter()
            .position(|&r| r == self.rank)
            .ok_or_else(|| Error::Precondition("sub(): caller not in member list".into()))?;
        for &m in members {
            self.check_rank(m, "sub")?;
        }
        let world_ranks: Vec<usize> = members.iter().map(|&r| self.ranks[r]).collect();
        // Deterministic child context from (parent ctx, member set) ONLY.
        // Crucially this consumes no parent sequence number: `sub` may be
        // called by a subset of ranks (e.g. only the masters in the
        // hierarchical allgather), and consuming a tag would desynchronize
        // the parent's collective-tag counter across ranks. Re-deriving the
        // same sub-communicator later therefore reuses its context id —
        // safe because matching is FIFO per (src, ctx, tag) and each rank
        // issues its collectives in program order, exactly like reusing an
        // MPI communicator.
        let mut h = splitmix(self.ctx ^ 0xA5A5_5A5A_DEAD_BEEF);
        for &w in &world_ranks {
            h = splitmix(h ^ (w as u64).wrapping_add(0x1234_5678));
        }
        SUB_COMMS_BUILT.fetch_add(1, Ordering::Relaxed);
        Ok(Comm {
            world_rank: self.world_rank,
            rank: my,
            ranks: Arc::new(world_ranks),
            ctx: h | 1, // never collide with the world ctx 0
            seq: Cell::new(0),
            world: self.world.clone(),
        })
    }

    /// Split this communicator by topology region: returns the caller's
    /// *local* communicator (all comm ranks in the same region, in rank
    /// order). Mirrors `MPI_Comm_split(comm, region, rank, &local)`.
    pub fn split_regions(&self) -> Result<Comm> {
        let topo = &self.world.topo;
        let my_region = topo.region_of(self.world_rank);
        let members: Vec<usize> = (0..self.size())
            .filter(|&r| topo.region_of(self.ranks[r]) == my_region)
            .collect();
        self.sub(&members)
    }

    /// Barrier that also propagates the virtual-clock maximum (used to
    /// separate timed phases; charges no message costs).
    pub fn barrier(&self) -> Result<()> {
        let p = self.size();
        if p <= 1 {
            return Ok(());
        }
        let tag = self.next_coll_tag();
        let mut dist = 1usize;
        while dist < p {
            let dst = (self.rank + dist) % p;
            let src = (self.rank + p - dist) % p;
            // One tag for the whole barrier is safe: every round receives
            // from a distinct source (dist < p are pairwise distinct).
            self.post(dst, tag, Vec::new(), false)?;
            let msg = self.take(Some(src), tag, false)?;
            if let Timing::Virtual(_) = &self.world.timing {
                let t = self.state().clock().max(msg.stamp);
                self.state().set_clock(t);
            }
            dist <<= 1;
        }
        Ok(())
    }

    /// Collectively reset clocks and traces (rank 0 clears between two
    /// barriers). Use between timed phases of a benchmark.
    pub fn reset_stats(&self) -> Result<()> {
        self.barrier()?;
        if self.rank == 0 {
            for s in &self.world.states {
                s.set_clock(0.0);
                s.trace.lock().expect("trace poisoned").clear();
            }
        }
        self.barrier()?;
        // barrier propagated a stale max; force-zero our clock again
        self.set_clock(0.0);
        Ok(())
    }
}

/// Completed-send request (buffered sends complete immediately).
#[derive(Debug)]
pub struct SendReq {
    _completed: bool,
}

impl SendReq {
    /// No-op: buffered sends are complete at creation.
    pub fn wait(self) {}
}

/// Pending-receive request.
#[derive(Debug)]
pub struct RecvReq {
    src: usize,
    tag: u64,
}

impl RecvReq {
    /// Block until the message arrives; decode as `T`.
    pub fn wait<T: Pod>(self, comm: &Comm) -> Result<Vec<T>> {
        comm.recv(self.src, self.tag)
    }

    /// Block until the message arrives; copy into `dst`.
    pub fn wait_into<T: Pod>(self, comm: &Comm, dst: &mut [T]) -> Result<()> {
        comm.recv_into(self.src, self.tag, dst)
    }
}

/// Wait on many receive requests, in order.
pub fn waitall<T: Pod>(comm: &Comm, reqs: Vec<RecvReq>) -> Result<Vec<Vec<T>>> {
    reqs.into_iter().map(|r| r.wait(comm)).collect()
}

fn splitmix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Topology;

    fn world() -> Topology {
        Topology::regions(2, 2)
    }

    #[test]
    fn ping_pong_roundtrip() {
        let run = CommWorld::run(&world(), Timing::Wallclock, |c| {
            if c.rank() == 0 {
                c.send(&[1u32, 2, 3], 1, 5).unwrap();
                c.recv::<u32>(1, 6).unwrap()
            } else if c.rank() == 1 {
                let v = c.recv::<u32>(0, 5).unwrap();
                c.send(&v.iter().map(|x| x * 2).collect::<Vec<_>>(), 0, 6).unwrap();
                v
            } else {
                vec![]
            }
        });
        assert_eq!(run.results[0], vec![2, 4, 6]);
        assert_eq!(run.results[1], vec![1, 2, 3]);
    }

    #[test]
    fn virtual_clock_charges_send_and_syncs_recv() {
        let m = MachineParams::uniform(1.0, 0.0); // α=1s, β=0
        let run = CommWorld::run(&world(), Timing::Virtual(m), |c| {
            if c.rank() == 0 {
                c.send(&[0u8; 4], 1, 1).unwrap();
            } else if c.rank() == 1 {
                c.recv::<u8>(0, 1).unwrap();
            }
            c.clock()
        });
        assert_eq!(run.results[0], 1.0); // charged α
        assert_eq!(run.results[1], 1.0); // synced to arrival
        assert_eq!(run.results[2], 0.0); // untouched
        assert_eq!(run.max_vtime(), 1.0);
    }

    #[test]
    fn chained_sends_accumulate_postal_cost() {
        // 0 -> 1 -> 2 -> 3, each hop α=1: final clock at rank 3 is 3.0.
        let m = MachineParams::uniform(1.0, 0.0);
        let run = CommWorld::run(&world(), Timing::Virtual(m), |c| {
            let r = c.rank();
            if r > 0 {
                c.recv::<u8>(r - 1, 9).unwrap();
            }
            if r < 3 {
                c.send(&[0u8], r + 1, 9).unwrap();
            }
            c.clock()
        });
        assert_eq!(run.results[3], 3.0);
    }

    #[test]
    fn trace_classifies_locality() {
        // regions(2,2): ranks {0,1} region 0, {2,3} region 1.
        let run = CommWorld::run(&world(), Timing::Wallclock, |c| {
            if c.rank() == 0 {
                c.send(&[1u8], 1, 1).unwrap(); // local
                c.send(&[1u8, 2], 2, 2).unwrap(); // non-local
            } else if c.rank() == 1 {
                c.recv::<u8>(0, 1).unwrap();
            } else if c.rank() == 2 {
                c.recv::<u8>(0, 2).unwrap();
            }
        });
        let t0 = &run.trace.per_rank[0];
        assert_eq!(t0.local_msgs, 1);
        assert_eq!(t0.nonlocal_msgs, 1);
        assert_eq!(t0.nonlocal_bytes, 2);
        assert_eq!(run.trace.max_nonlocal_msgs(), 1);
    }

    #[test]
    fn sub_communicator_ranks_and_isolation() {
        let run = CommWorld::run(&world(), Timing::Wallclock, |c| {
            let local = c.split_regions().unwrap();
            assert_eq!(local.size(), 2);
            // exchange within the region using local ranks
            let peer = 1 - local.rank();
            let got = local
                .sendrecv(&[c.world_rank() as u32], peer, peer, 3)
                .unwrap();
            got[0] as usize
        });
        // each rank got its region partner's world rank
        assert_eq!(run.results, vec![1, 0, 3, 2]);
    }

    #[test]
    fn sub_comm_messages_do_not_leak_across_contexts() {
        let run = CommWorld::run(&world(), Timing::Wallclock, |c| {
            let local = c.split_regions().unwrap();
            if c.rank() == 0 {
                // send on world ctx and on local ctx with the same tag
                c.send(&[7u8], 1, 4).unwrap();
                local.send(&[9u8], 1, 4).unwrap();
                0
            } else if c.rank() == 1 {
                // local recv must get the local message, not the world one
                let l: Vec<u8> = local.recv(0, 4).unwrap();
                let w: Vec<u8> = c.recv(0, 4).unwrap();
                (l[0] as usize) * 10 + w[0] as usize
            } else {
                0
            }
        });
        assert_eq!(run.results[1], 97);
    }

    #[test]
    fn irecv_waitall_order() {
        let run = CommWorld::run(&world(), Timing::Wallclock, |c| {
            if c.rank() == 0 {
                let r1 = c.irecv(1, 11);
                let r2 = c.irecv(2, 12);
                let got = waitall::<u32>(c, vec![r1, r2]).unwrap();
                got.concat()
            } else if c.rank() <= 2 {
                c.send(&[c.rank() as u32 * 100], 0, 10 + c.rank() as u64).unwrap();
                vec![]
            } else {
                vec![]
            }
        });
        assert_eq!(run.results[0], vec![100, 200]);
    }

    #[test]
    fn barrier_syncs_clocks_without_charging() {
        let m = MachineParams::uniform(1.0, 0.0);
        let run = CommWorld::run(&world(), Timing::Virtual(m), |c| {
            if c.rank() == 0 {
                c.send(&[0u8], 1, 1).unwrap(); // clock 1.0
            } else if c.rank() == 1 {
                c.recv::<u8>(0, 1).unwrap();
                c.send(&[0u8], 0, 2).unwrap(); // clock 2.0
            }
            if c.rank() == 0 {
                c.recv::<u8>(1, 2).unwrap();
            }
            c.barrier().unwrap();
            c.clock()
        });
        // everyone at least at the max (2.0), and no extra message charges
        for (r, &t) in run.results.iter().enumerate() {
            assert!(t >= 2.0, "rank {r} clock {t}");
        }
        let total_msgs: u64 = run.trace.per_rank.iter().map(|t| t.total_msgs()).sum();
        assert_eq!(total_msgs, 2); // only the two charged sends
    }

    #[test]
    fn reset_stats_zeroes_clock_and_trace() {
        let m = MachineParams::uniform(1.0, 0.0);
        let run = CommWorld::run(&world(), Timing::Virtual(m), |c| {
            if c.rank() == 0 {
                c.send(&[0u8], 1, 1).unwrap();
            } else if c.rank() == 1 {
                c.recv::<u8>(0, 1).unwrap();
            }
            c.reset_stats().unwrap();
            (c.clock(), c.trace_snapshot().total_msgs())
        });
        for &(t, m) in &run.results {
            assert_eq!(t, 0.0);
            assert_eq!(m, 0);
        }
    }

    #[test]
    fn datatype_mismatch_detected() {
        let run = CommWorld::run(&world(), Timing::Wallclock, |c| {
            if c.rank() == 0 {
                c.send(&[1u8, 2, 3], 1, 1).unwrap();
                true
            } else if c.rank() == 1 {
                c.recv::<u32>(0, 1).is_err()
            } else {
                true
            }
        });
        assert!(run.results[1]);
    }

    #[test]
    fn reserved_tag_blocks_are_disjoint_and_ordered() {
        let run = CommWorld::run(&world(), Timing::Wallclock, |c| {
            let a = c.reserve_coll_tags(4);
            let b = c.next_coll_tag();
            let d = c.reserve_coll_tags(2);
            (a, b, d)
        });
        for &(a, b, d) in &run.results {
            assert_eq!(a, COLL_TAG_BASE);
            assert_eq!(b, a + 4);
            assert_eq!(d, b + 1);
        }
    }

    #[test]
    fn retained_handle_interoperates_with_original() {
        let run = CommWorld::run(&world(), Timing::Wallclock, |c| {
            let tag = c.reserve_coll_tags(1);
            let held = c.retain();
            if c.rank() == 0 {
                // send through the retained handle, receive on the original
                held.send(&[5u8], 1, tag).unwrap();
                0
            } else if c.rank() == 1 {
                c.recv::<u8>(0, tag).unwrap()[0] as usize
            } else {
                0
            }
        });
        assert_eq!(run.results[1], 5);
    }

    #[test]
    fn sub_counter_increments_per_construction() {
        let before = sub_comms_built();
        let run = CommWorld::run(&world(), Timing::Wallclock, |c| {
            let local = c.split_regions().unwrap();
            // retaining is NOT a construction
            let _held = local.retain();
            local.size()
        });
        assert!(run.results.iter().all(|&s| s == 2));
        // 4 ranks each built exactly one sub-communicator
        assert!(sub_comms_built() >= before + 4);
    }

    #[test]
    fn rank_out_of_range_errors() {
        let run = CommWorld::run(&world(), Timing::Wallclock, |c| {
            c.send(&[0u8], 99, 0).is_err()
        });
        assert!(run.results.iter().all(|&x| x));
    }
}
