//! The sweep/measurement engine: run any planned collective at a given
//! topology and machine model, and report modeled time, wall time,
//! correctness and the locality-classified traffic trace.
//!
//! This is what the figure harness, the examples and the integration tests
//! drive. One [`run_allgather`] / [`run_allreduce`] / [`run_alltoall`] /
//! [`run_reduce_scatter`] call = one data point of a paper figure. The
//! `run_*_repeated` variants are benchmark-shaped: every rank **plans
//! once** and executes `warmup + iters` times, with a clock-syncing
//! barrier between iterations — the paper's timed loop with communicators
//! created once outside the timed region.

use std::time::Instant;

use crate::collectives::{self, Algorithm, Counts, OpKind, Schedule, Shape};
use crate::comm::{Comm, CommWorld, Timing};
use crate::error::Error;
use crate::model::{cost, MachineParams};
use crate::topology::Topology;
use crate::trace::TraceSummary;
use crate::util::stats;

/// Predicted completion time from the per-rank schedules the workers
/// returned, or 0.0 when prediction does not apply (wall-clock timing, a
/// failed run, or the zero-length no-op plan).
fn predicted_from(
    scheds: Vec<Option<Schedule>>,
    topo: &Topology,
    machine: Option<&MachineParams>,
) -> f64 {
    let Some(machine) = machine else { return 0.0 };
    let scheds: Option<Vec<Schedule>> = scheds.into_iter().collect();
    let Some(scheds) = scheds else { return 0.0 };
    if scheds.len() != topo.size() {
        return 0.0;
    }
    let world: Vec<usize> = (0..topo.size()).collect();
    cost::predict(&scheds, topo, &world, machine).unwrap_or(0.0)
}

/// Result of one allgather execution over a world.
#[derive(Debug, Clone)]
pub struct AllgatherReport {
    pub algorithm: Algorithm,
    /// Ranks in the world.
    pub p: usize,
    /// Elements contributed per rank (u32 values, as in the paper's §5).
    pub n: usize,
    /// Modeled completion time (max final virtual clock), seconds.
    pub vtime: f64,
    /// Schedule-derived predicted completion time
    /// ([`crate::model::cost::predict`]), seconds; 0.0 under wall-clock
    /// timing or when no schedule is available.
    pub predicted: f64,
    /// Wall-clock time of the in-process execution, seconds.
    pub wall: f64,
    /// True if every rank produced the expected gathered array.
    pub verified: bool,
    /// Send-side traffic accounting.
    pub trace: TraceSummary,
    /// Per-rank error strings, if the algorithm failed anywhere.
    pub errors: Vec<String>,
}

/// Run `algo` once over `topo` with `n` `u32` values per rank under the
/// virtual-clock transport parameterized by `machine`.
///
/// The paper's measurements use two 4-byte integers per process (§5);
/// `n = 2` reproduces that.
pub fn run_allgather(
    algo: Algorithm,
    topo: &Topology,
    machine: &MachineParams,
    n: usize,
) -> AllgatherReport {
    run_allgather_timed(algo, topo, Timing::Virtual(machine.clone()), n)
}

/// Run `algo` once with an explicit [`Timing`] mode (wall-clock mode is
/// used by the perf benches). Internally plan + execute, like every other
/// call site of the collective layer.
pub fn run_allgather_timed(
    algo: Algorithm,
    topo: &Topology,
    timing: Timing,
    n: usize,
) -> AllgatherReport {
    let p = topo.size();
    let machine = match &timing {
        Timing::Virtual(m) => Some(m.clone()),
        Timing::Wallclock => None,
    };
    let expected: Vec<u32> = (0..p).flat_map(|r| contribution(r, n)).collect();
    let start = Instant::now();
    let run =
        CommWorld::run(topo, timing, |c| -> crate::error::Result<(bool, Option<Schedule>)> {
            let mine = contribution(c.rank(), n);
            let mut plan = collectives::plan_allgather::<u32>(algo, c, Shape::elems(n))?;
            let sched = plan.schedule().cloned();
            let mut out = vec![0u32; n * p];
            plan.execute(&mine, &mut out)?;
            Ok((out == expected, sched))
        });
    let wall = start.elapsed().as_secs_f64();
    let mut verified = true;
    let mut errors = Vec::new();
    let mut scheds: Vec<Option<Schedule>> = Vec::with_capacity(p);
    for (rank, res) in run.results.into_iter().enumerate() {
        match res {
            Ok((true, s)) => scheds.push(s),
            Ok((false, s)) => {
                verified = false;
                errors.push(format!("rank {rank}: wrong gathered data"));
                scheds.push(s);
            }
            Err(e) => {
                verified = false;
                errors.push(format!("rank {rank}: {e}"));
                scheds.push(None);
            }
        }
    }
    let predicted =
        if verified { predicted_from(scheds, topo, machine.as_ref()) } else { 0.0 };
    AllgatherReport {
        algorithm: algo,
        p,
        n,
        vtime: run.vtimes.iter().copied().fold(0.0, f64::max),
        predicted,
        wall,
        verified,
        trace: run.trace,
        errors,
    }
}

/// Result of a plan-once/execute-many run.
#[derive(Debug, Clone)]
pub struct RepeatedReport {
    pub algorithm: Algorithm,
    pub p: usize,
    pub n: usize,
    /// Unmeasured and measured execution counts.
    pub warmup: usize,
    pub iters: usize,
    /// Modeled completion time of each measured execution (barrier-to-end
    /// max clock delta), seconds.
    pub per_iter_vtime: Vec<f64>,
    /// Median of [`RepeatedReport::per_iter_vtime`] — the figure value.
    pub median_vtime: f64,
    /// Schedule-derived predicted completion time per execution
    /// ([`crate::model::cost::predict`]); the figures' model overlay.
    pub predicted: f64,
    /// Wall-clock time of the whole in-process run, seconds.
    pub wall: f64,
    /// True if every execution on every rank produced the expected array.
    pub verified: bool,
    /// Per-execution traffic (total counters divided by `warmup + iters`;
    /// exact because every execution sends the identical schedule).
    pub trace: TraceSummary,
    pub errors: Vec<String>,
}

/// Plan once per rank, execute `warmup + iters` times under virtual
/// timing, measuring each iteration's modeled completion separately.
///
/// A clock-propagating barrier (charging no message costs) separates the
/// iterations, so every measured delta equals the single-shot modeled
/// latency — the paper's timed-loop methodology.
pub fn run_allgather_repeated(
    algo: Algorithm,
    topo: &Topology,
    machine: &MachineParams,
    n: usize,
    warmup: usize,
    iters: usize,
) -> RepeatedReport {
    assert!(iters > 0, "need at least one measured iteration");
    let p = topo.size();
    let total = warmup + iters;
    let expected: Vec<u32> = (0..p).flat_map(|r| contribution(r, n)).collect();
    let start = Instant::now();
    let run = CommWorld::run(topo, Timing::Virtual(machine.clone()), |c: &mut Comm| {
        repeated_worker(c, algo, n, total, &expected)
    });
    let wall = start.elapsed().as_secs_f64();
    let (verified, errors) = collect_errors(&run.results);
    // Iteration i's modeled completion: all ranks start at the same
    // barrier-synced clock; the span is the max end over ranks minus that
    // shared start.
    let per_iter_vtime = per_iter_vtimes(&run.results, warmup, total, verified);
    let median_vtime = stats::median(&per_iter_vtime);
    let predicted = if verified {
        let scheds: Vec<Option<Schedule>> = run
            .results
            .iter()
            .map(|r| r.as_ref().ok().and_then(|(_, s)| s.clone()))
            .collect();
        predicted_from(scheds, topo, Some(machine))
    } else {
        0.0
    };
    // Only a fully-verified run is guaranteed to have executed the
    // identical schedule `total` times; a mid-loop failure leaves raw
    // (non-divisible) counters.
    let trace = if verified { run.trace.per_op(total as u64) } else { run.trace };
    RepeatedReport {
        algorithm: algo,
        p,
        n,
        warmup,
        iters,
        median_vtime,
        predicted,
        per_iter_vtime,
        wall,
        verified,
        trace,
        errors,
    }
}

/// What every repeated worker returns: the per-iteration `(start, end)`
/// clock spans plus the plan's schedule (for cost prediction).
type Spans = (Vec<(f64, f64)>, Option<Schedule>);

/// Per-rank body of [`run_allgather_repeated`]: plan once, then
/// barrier-separated executions recording `(start, end)` clock spans.
fn repeated_worker(
    c: &Comm,
    algo: Algorithm,
    n: usize,
    total: usize,
    expected: &[u32],
) -> crate::error::Result<Spans> {
    let p = c.size();
    let mine = contribution(c.rank(), n);
    let mut plan = collectives::plan_allgather::<u32>(algo, c, Shape::elems(n))?;
    let sched = plan.schedule().cloned();
    let mut out = vec![0u32; n * p];
    let mut spans = Vec::with_capacity(total);
    for _ in 0..total {
        c.barrier()?; // sync clocks; charges no messages
        let t0 = c.clock();
        plan.execute(&mine, &mut out)?;
        if out != expected {
            return Err(Error::Precondition("wrong gathered data".into()));
        }
        spans.push((t0, c.clock()));
    }
    Ok((spans, sched))
}

fn collect_errors<R>(results: &[crate::error::Result<R>]) -> (bool, Vec<String>) {
    let mut verified = true;
    let mut errors = Vec::new();
    for (rank, res) in results.iter().enumerate() {
        if let Err(e) = res {
            verified = false;
            errors.push(format!("rank {rank}: {e}"));
        }
    }
    (verified, errors)
}

/// The canonical `u32` contribution used by the sweep engine.
fn contribution(rank: usize, n: usize) -> Vec<u32> {
    (0..n).map(|j| (rank * 131_071 + j) as u32).collect()
}

/// Result of one allreduce/alltoall execution over a world. The allgather
/// twin is [`AllgatherReport`] (kept separate for its typed
/// [`Algorithm`] field and figure call sites).
#[derive(Debug, Clone)]
pub struct OpReport {
    pub op: OpKind,
    /// Registry name of the algorithm.
    pub algorithm: String,
    pub p: usize,
    /// Elements per rank (per destination block, for alltoall).
    pub n: usize,
    /// Modeled completion time (max final virtual clock), seconds.
    pub vtime: f64,
    /// Schedule-derived predicted completion time, seconds.
    pub predicted: f64,
    /// Wall-clock time of the in-process execution, seconds.
    pub wall: f64,
    /// True if every rank produced the expected result.
    pub verified: bool,
    pub trace: TraceSummary,
    pub errors: Vec<String>,
}

/// Result of a plan-once/execute-many allreduce/alltoall run.
#[derive(Debug, Clone)]
pub struct RepeatedOpReport {
    pub op: OpKind,
    pub algorithm: String,
    pub p: usize,
    pub n: usize,
    pub warmup: usize,
    pub iters: usize,
    pub per_iter_vtime: Vec<f64>,
    pub median_vtime: f64,
    /// Schedule-derived predicted completion time per execution.
    pub predicted: f64,
    pub wall: f64,
    pub verified: bool,
    /// Per-execution traffic (see [`RepeatedReport::trace`]).
    pub trace: TraceSummary,
    pub errors: Vec<String>,
}

/// The canonical `u64` allreduce contribution (u64 so the sum never
/// overflows at any supported world size).
fn reduce_contribution(rank: usize, n: usize) -> Vec<u64> {
    (0..n).map(|j| (rank * 131_071 + j) as u64).collect()
}

fn reduce_expected(p: usize, n: usize) -> Vec<u64> {
    (0..n)
        .map(|j| (0..p).map(|r| (r * 131_071 + j) as u64).sum())
        .collect()
}

/// The canonical alltoall send buffer: block `j`, element `e` of rank `i`
/// is unique per `(i, j, e)`.
fn a2a_send(rank: usize, p: usize, n: usize) -> Vec<u64> {
    (0..p * n)
        .map(|x| (rank * 1_000_003 + (x / n) * 1_009) as u64 + (x % n) as u64)
        .collect()
}

fn a2a_expected(rank: usize, p: usize, n: usize) -> Vec<u64> {
    (0..p * n)
        .map(|x| ((x / n) * 1_000_003 + rank * 1_009) as u64 + (x % n) as u64)
        .collect()
}

/// The canonical reduce-scatter result on `rank`: the elementwise sum over
/// all ranks of their block destined here (inputs are [`a2a_send`]-shaped —
/// reduce-scatter consumes the same `n·p` block layout alltoall does).
fn rs_expected(rank: usize, p: usize, n: usize) -> Vec<u64> {
    (0..n)
        .map(|j| (0..p).map(|r| (r * 1_000_003 + rank * 1_009) as u64 + j as u64).sum())
        .collect()
}

/// The canonical allgatherv result: every rank's
/// [`collectives::canonical_contribution`] (sized by its count)
/// concatenated in rank order.
fn agv_expected(counts: &Counts) -> Vec<u64> {
    (0..counts.len())
        .flat_map(|r| collectives::canonical_contribution(r, counts.get(r)))
        .collect()
}

/// The canonical reduce-scatter-v send buffer on `rank`: block `b` holds
/// `counts[b]` elements unique per `(rank, b, j)` — the ragged analogue of
/// [`a2a_send`].
fn rsv_send(rank: usize, counts: &Counts) -> Vec<u64> {
    (0..counts.len())
        .flat_map(|b| (0..counts.get(b)).map(move |j| (rank * 1_000_003 + b * 1_009 + j) as u64))
        .collect()
}

/// The canonical reduce-scatter-v result on `rank`: the elementwise sum of
/// every rank's block destined here (`counts[rank]` elements).
fn rsv_expected(rank: usize, p: usize, counts: &Counts) -> Vec<u64> {
    (0..counts.get(rank))
        .map(|j| (0..p).map(|r| (r * 1_000_003 + rank * 1_009 + j) as u64).sum())
        .collect()
}

/// Shared per-rank body of every repeated op runner: plan once via
/// `make_plan`-style closures, then barrier-separated executions recording
/// `(start, end)` clock spans and checking against `expected`.
fn repeated_spans<E>(
    c: &Comm,
    total: usize,
    expected: &[u64],
    sched: Option<Schedule>,
    mut exec: E,
) -> crate::error::Result<Spans>
where
    E: FnMut(&Comm, &mut Vec<u64>) -> crate::error::Result<()>,
{
    let mut out = vec![0u64; expected.len()];
    let mut spans = Vec::with_capacity(total);
    for _ in 0..total {
        c.barrier()?; // sync clocks; charges no messages
        let t0 = c.clock();
        exec(c, &mut out)?;
        if out != expected {
            return Err(Error::Precondition("wrong collective result".into()));
        }
        spans.push((t0, c.clock()));
    }
    Ok((spans, sched))
}

/// Extract per-iteration modeled latencies from the recorded spans (only
/// meaningful when every rank verified).
fn per_iter_vtimes(
    results: &[crate::error::Result<Spans>],
    warmup: usize,
    total: usize,
    verified: bool,
) -> Vec<f64> {
    let mut per_iter = Vec::with_capacity(total - warmup);
    if verified {
        for i in warmup..total {
            let start_i = results[0].as_ref().expect("verified").0[i].0;
            let end_i = results
                .iter()
                .map(|r| r.as_ref().expect("verified").0[i].1)
                .fold(0.0f64, f64::max);
            per_iter.push(end_i - start_i);
        }
    }
    per_iter
}

/// Run one allreduce by registry name under the virtual-clock transport.
pub fn run_allreduce(
    algo: &str,
    topo: &Topology,
    machine: &MachineParams,
    n: usize,
) -> OpReport {
    let rep = run_allreduce_repeated(algo, topo, machine, n, 0, 1);
    repeated_to_single(rep)
}

/// Run one alltoall by registry name under the virtual-clock transport.
pub fn run_alltoall(
    algo: &str,
    topo: &Topology,
    machine: &MachineParams,
    n: usize,
) -> OpReport {
    let rep = run_alltoall_repeated(algo, topo, machine, n, 0, 1);
    repeated_to_single(rep)
}

/// Run one reduce-scatter by registry name under the virtual-clock
/// transport.
pub fn run_reduce_scatter(
    algo: &str,
    topo: &Topology,
    machine: &MachineParams,
    n: usize,
) -> OpReport {
    let rep = run_reduce_scatter_repeated(algo, topo, machine, n, 0, 1);
    repeated_to_single(rep)
}

/// Run one ragged allgather (allgatherv) by registry name under the
/// virtual-clock transport. The report's `n` is the total gathered element
/// count (`counts.total()`).
pub fn run_allgatherv(
    algo: &str,
    topo: &Topology,
    machine: &MachineParams,
    counts: &Counts,
) -> OpReport {
    let rep = run_allgatherv_repeated(algo, topo, machine, counts, 0, 1);
    repeated_to_single(rep)
}

/// Run one ragged reduce-scatter (reduce_scatter_v) by registry name under
/// the virtual-clock transport. The report's `n` is the total reduced
/// element count (`counts.total()`).
pub fn run_reduce_scatter_v(
    algo: &str,
    topo: &Topology,
    machine: &MachineParams,
    counts: &Counts,
) -> OpReport {
    let rep = run_reduce_scatter_v_repeated(algo, topo, machine, counts, 0, 1);
    repeated_to_single(rep)
}

fn repeated_to_single(rep: RepeatedOpReport) -> OpReport {
    OpReport {
        op: rep.op,
        algorithm: rep.algorithm,
        p: rep.p,
        n: rep.n,
        vtime: rep.median_vtime,
        predicted: rep.predicted,
        wall: rep.wall,
        verified: rep.verified,
        trace: rep.trace,
        errors: rep.errors,
    }
}

/// Shared outer loop of the repeated op runners: spawn the world, run the
/// per-rank `worker`, collect spans/errors/traffic into the report.
#[allow(clippy::too_many_arguments)]
fn run_op_repeated<F>(
    op: OpKind,
    algo: &str,
    topo: &Topology,
    machine: &MachineParams,
    n: usize,
    warmup: usize,
    iters: usize,
    worker: F,
) -> RepeatedOpReport
where
    F: Fn(&Comm, usize) -> crate::error::Result<Spans> + Sync,
{
    assert!(iters > 0, "need at least one measured iteration");
    let p = topo.size();
    let total = warmup + iters;
    let start = Instant::now();
    let run =
        CommWorld::run(topo, Timing::Virtual(machine.clone()), |c: &mut Comm| worker(c, total));
    let wall = start.elapsed().as_secs_f64();
    let (verified, errors) = collect_errors(&run.results);
    let per_iter_vtime = per_iter_vtimes(&run.results, warmup, total, verified);
    let median_vtime = stats::median(&per_iter_vtime);
    let predicted = if verified {
        let scheds: Vec<Option<Schedule>> = run
            .results
            .iter()
            .map(|r| r.as_ref().ok().and_then(|(_, s)| s.clone()))
            .collect();
        predicted_from(scheds, topo, Some(machine))
    } else {
        0.0
    };
    let trace = if verified { run.trace.per_op(total as u64) } else { run.trace };
    RepeatedOpReport {
        op,
        algorithm: algo.to_string(),
        p,
        n,
        warmup,
        iters,
        per_iter_vtime,
        median_vtime,
        predicted,
        wall,
        verified,
        trace,
        errors,
    }
}

/// Plan once per rank, execute an allreduce `warmup + iters` times under
/// virtual timing (the allreduce twin of [`run_allgather_repeated`]).
pub fn run_allreduce_repeated(
    algo: &str,
    topo: &Topology,
    machine: &MachineParams,
    n: usize,
    warmup: usize,
    iters: usize,
) -> RepeatedOpReport {
    let expected = reduce_expected(topo.size(), n);
    run_op_repeated(OpKind::Allreduce, algo, topo, machine, n, warmup, iters, |c, total| {
        let mut plan = collectives::plan_allreduce::<u64>(algo, c, Shape::elems(n))?;
        let sched = plan.schedule().cloned();
        let mine = reduce_contribution(c.rank(), n);
        repeated_spans(c, total, &expected, sched, |_, out| plan.execute(&mine, out))
    })
}

/// Plan once per rank, execute an alltoall `warmup + iters` times under
/// virtual timing (the alltoall twin of [`run_allgather_repeated`]).
pub fn run_alltoall_repeated(
    algo: &str,
    topo: &Topology,
    machine: &MachineParams,
    n: usize,
    warmup: usize,
    iters: usize,
) -> RepeatedOpReport {
    let p = topo.size();
    run_op_repeated(OpKind::Alltoall, algo, topo, machine, n, warmup, iters, |c, total| {
        let mut plan = collectives::plan_alltoall::<u64>(algo, c, Shape::elems(n))?;
        let sched = plan.schedule().cloned();
        let mine = a2a_send(c.rank(), p, n);
        let expected = a2a_expected(c.rank(), p, n);
        repeated_spans(c, total, &expected, sched, |_, out| plan.execute(&mine, out))
    })
}

/// Plan once per rank, execute a reduce-scatter `warmup + iters` times
/// under virtual timing (the reduce-scatter twin of
/// [`run_allgather_repeated`]).
pub fn run_reduce_scatter_repeated(
    algo: &str,
    topo: &Topology,
    machine: &MachineParams,
    n: usize,
    warmup: usize,
    iters: usize,
) -> RepeatedOpReport {
    let p = topo.size();
    run_op_repeated(OpKind::ReduceScatter, algo, topo, machine, n, warmup, iters, |c, total| {
        let mut plan = collectives::plan_reduce_scatter::<u64>(algo, c, Shape::elems(n))?;
        let sched = plan.schedule().cloned();
        let mine = a2a_send(c.rank(), p, n);
        let expected = rs_expected(c.rank(), p, n);
        repeated_spans(c, total, &expected, sched, |_, out| plan.execute(&mine, out))
    })
}

/// Plan once per rank, execute an allgatherv `warmup + iters` times under
/// virtual timing (the ragged twin of [`run_allgather_repeated`]; every
/// rank contributes `counts[rank]` elements).
pub fn run_allgatherv_repeated(
    algo: &str,
    topo: &Topology,
    machine: &MachineParams,
    counts: &Counts,
    warmup: usize,
    iters: usize,
) -> RepeatedOpReport {
    let expected = agv_expected(counts);
    let total_elems = counts.total();
    run_op_repeated(
        OpKind::Allgatherv,
        algo,
        topo,
        machine,
        total_elems,
        warmup,
        iters,
        |c, total| {
            let mut plan = collectives::plan_allgatherv::<u64>(algo, c, counts)?;
            let sched = plan.schedule().cloned();
            let mine = collectives::canonical_contribution(c.rank(), counts.get(c.rank()));
            repeated_spans(c, total, &expected, sched, |_, out| plan.execute(&mine, out))
        },
    )
}

/// Plan once per rank, execute a reduce-scatter-v `warmup + iters` times
/// under virtual timing (the ragged twin of
/// [`run_reduce_scatter_repeated`]; rank `r` receives `counts[r]` reduced
/// elements).
pub fn run_reduce_scatter_v_repeated(
    algo: &str,
    topo: &Topology,
    machine: &MachineParams,
    counts: &Counts,
    warmup: usize,
    iters: usize,
) -> RepeatedOpReport {
    let p = topo.size();
    let total_elems = counts.total();
    run_op_repeated(
        OpKind::ReduceScatterV,
        algo,
        topo,
        machine,
        total_elems,
        warmup,
        iters,
        |c, total| {
            let mut plan = collectives::plan_reduce_scatter_v::<u64>(algo, c, counts)?;
            let sched = plan.schedule().cloned();
            let mine = rsv_send(c.rank(), counts);
            let expected = rsv_expected(c.rank(), p, counts);
            repeated_spans(c, total, &expected, sched, |_, out| plan.execute(&mine, out))
        },
    )
}

/// Result of one fused-vs-sequential comparison run
/// ([`run_fused`]): the same constituents executed once as a fused
/// schedule and once back to back, with modeled times, IR predictions and
/// traffic for both sides.
#[derive(Debug, Clone)]
pub struct FusedReport {
    /// Constituent labels (`op/algo@n`).
    pub specs: Vec<String>,
    pub p: usize,
    /// Modeled completion of the single fused execution.
    pub fused_vtime: f64,
    /// [`cost::predict`] over the fused schedules — equals
    /// [`FusedReport::fused_vtime`] exactly (same invariant every
    /// single-plan schedule holds).
    pub fused_predicted: f64,
    /// Modeled completion of the barrier-separated sequential executions.
    pub seq_vtime: f64,
    /// Sum of the constituents' predicted completions.
    pub seq_predicted: f64,
    /// Per-execution traffic of the fused schedule (the fused world runs
    /// twice — staged oracle + measured view — and the counters divide
    /// exactly, like the repeated runners').
    pub fused_trace: TraceSummary,
    /// Accumulated traffic of the sequential executions.
    pub seq_trace: TraceSummary,
    /// True if both sides produced the expected result of every
    /// constituent on every rank.
    pub verified: bool,
    pub errors: Vec<String>,
}

/// Per-rank counts of a fused constituent: the spec's own ragged counts,
/// or a uniform `n`-per-rank vector for the classic ops (so the ragged ops
/// stay well-defined even on a uniform spec).
fn spec_counts(spec: &collectives::FuseSpec, p: usize) -> Counts {
    spec.counts.clone().unwrap_or_else(|| Counts::uniform(spec.n, p))
}

/// Canonical input of one fused constituent (u64 payloads, like the
/// repeated runners).
fn fused_input(spec: &collectives::FuseSpec, rank: usize, p: usize) -> Vec<u64> {
    match spec.op {
        OpKind::Allgather => collectives::canonical_contribution(rank, spec.n),
        OpKind::Allreduce => reduce_contribution(rank, spec.n),
        OpKind::Alltoall | OpKind::ReduceScatter => a2a_send(rank, p, spec.n),
        OpKind::Allgatherv => {
            collectives::canonical_contribution(rank, spec_counts(spec, p).get(rank))
        }
        OpKind::ReduceScatterV => rsv_send(rank, &spec_counts(spec, p)),
    }
}

/// Expected result of one fused constituent on `rank`.
fn fused_expected(spec: &collectives::FuseSpec, rank: usize, p: usize) -> Vec<u64> {
    match spec.op {
        OpKind::Allgather => collectives::expected_result(p, spec.n),
        OpKind::Allreduce => reduce_expected(p, spec.n),
        OpKind::Alltoall => a2a_expected(rank, p, spec.n),
        OpKind::ReduceScatter => rs_expected(rank, p, spec.n),
        OpKind::Allgatherv => agv_expected(&spec_counts(spec, p)),
        OpKind::ReduceScatterV => rsv_expected(rank, p, &spec_counts(spec, p)),
    }
}

/// Execute `specs` as a [`collectives::FusedPlan`] and once sequentially
/// (barrier-separated, plan-once per constituent), both under the
/// virtual-clock transport, and report modeled times, IR-predicted times
/// and traffic for both sides.
///
/// The fused world executes **twice**, barrier-separated like a warmup
/// iteration: once through the staged-copy path
/// ([`collectives::FusedPlan::execute`]) as the conformance oracle, then
/// once through the zero-copy segmented-view path
/// ([`collectives::FusedPlan::execute_view`]) — the measured execution.
/// Any byte of divergence between the two fails verification, so every
/// `run_fused` call site doubles as a staged-vs-view conformance check.
/// [`FusedReport::fused_trace`] stays per-execution (the doubled counters
/// divide exactly — both executions send the identical schedule).
pub fn run_fused(
    specs: &[collectives::FuseSpec],
    topo: &Topology,
    machine: &MachineParams,
) -> FusedReport {
    use crate::collectives::{
        AllgathervRegistry, AllreduceRegistry, AlltoallRegistry, CollectivePlan, PlanSpec,
        ReduceScatterRegistry, ReduceScattervRegistry, Registry,
    };
    let p = topo.size();

    // --- fused world: one plan, one execution -----------------------------
    let fused_run = CommWorld::run(
        topo,
        Timing::Virtual(machine.clone()),
        |c| -> crate::error::Result<((f64, f64), Option<Schedule>)> {
            let mut plan = collectives::plan_fused::<u64>(c, specs)?;
            let sched = plan.schedule().cloned();
            let ins: Vec<Vec<u64>> = specs.iter().map(|s| fused_input(s, c.rank(), p)).collect();
            let want: Vec<Vec<u64>> =
                specs.iter().map(|s| fused_expected(s, c.rank(), p)).collect();
            let mut staged: Vec<Vec<u64>> = want.iter().map(|w| vec![0u64; w.len()]).collect();
            let mut outs: Vec<Vec<u64>> = want.iter().map(|w| vec![0u64; w.len()]).collect();
            // Staged oracle execution (unmeasured, like a warmup iteration).
            c.barrier()?;
            {
                let in_refs: Vec<&[u64]> = ins.iter().map(|v| v.as_slice()).collect();
                let mut out_refs: Vec<&mut [u64]> =
                    staged.iter_mut().map(|v| v.as_mut_slice()).collect();
                plan.execute(&in_refs, &mut out_refs)?;
            }
            // Measured execution: the zero-copy segmented-view hot path.
            c.barrier()?;
            let t0 = c.clock();
            {
                let in_refs: Vec<&[u64]> = ins.iter().map(|v| v.as_slice()).collect();
                let mut out_refs: Vec<&mut [u64]> =
                    outs.iter_mut().map(|v| v.as_mut_slice()).collect();
                plan.execute_view(&in_refs, &mut out_refs)?;
            }
            let span = (t0, c.clock());
            if staged != want {
                return Err(Error::Precondition("fused execution produced wrong data".into()));
            }
            if outs != staged {
                return Err(Error::Precondition(
                    "zero-copy view execution diverged from the staged oracle".into(),
                ));
            }
            Ok((span, sched))
        },
    );

    // --- sequential world: one plan per constituent, back to back ---------
    let seq_run = CommWorld::run(
        topo,
        Timing::Virtual(machine.clone()),
        |c| -> crate::error::Result<Vec<(f64, f64)>> {
            let mut spans = Vec::with_capacity(specs.len());
            for s in specs {
                let mine = fused_input(s, c.rank(), p);
                let want = fused_expected(s, c.rank(), p);
                let mut out = vec![0u64; want.len()];
                c.barrier()?;
                let t0 = c.clock();
                match s.op {
                    OpKind::Allgather => {
                        let mut plan = Registry::<u64>::standard()
                            .plan_uniform(&s.algo, c, Shape::elems(s.n))?;
                        plan.execute(&mine, &mut out)?;
                    }
                    OpKind::Allreduce => {
                        let mut plan = AllreduceRegistry::<u64>::standard()
                            .plan_uniform(&s.algo, c, Shape::elems(s.n))?;
                        plan.execute(&mine, &mut out)?;
                    }
                    OpKind::Alltoall => {
                        let mut plan = AlltoallRegistry::<u64>::standard()
                            .plan_uniform(&s.algo, c, Shape::elems(s.n))?;
                        plan.execute(&mine, &mut out)?;
                    }
                    OpKind::ReduceScatter => {
                        let mut plan = ReduceScatterRegistry::<u64>::standard()
                            .plan_uniform(&s.algo, c, Shape::elems(s.n))?;
                        plan.execute(&mine, &mut out)?;
                    }
                    OpKind::Allgatherv => {
                        let mut plan = AllgathervRegistry::<u64>::standard()
                            .plan(&s.algo, c, &PlanSpec::ragged(spec_counts(s, p)))?;
                        plan.execute(&mine, &mut out)?;
                    }
                    OpKind::ReduceScatterV => {
                        let mut plan = ReduceScattervRegistry::<u64>::standard()
                            .plan(&s.algo, c, &PlanSpec::ragged(spec_counts(s, p)))?;
                        plan.execute(&mine, &mut out)?;
                    }
                }
                if out != want {
                    return Err(Error::Precondition(
                        "sequential execution produced wrong data".into(),
                    ));
                }
                spans.push((t0, c.clock()));
            }
            Ok(spans)
        },
    );

    let mut errors = Vec::new();
    for (rank, r) in fused_run.results.iter().enumerate() {
        if let Err(e) = r {
            errors.push(format!("fused rank {rank}: {e}"));
        }
    }
    for (rank, r) in seq_run.results.iter().enumerate() {
        if let Err(e) = r {
            errors.push(format!("sequential rank {rank}: {e}"));
        }
    }
    let verified = errors.is_empty();

    let (fused_vtime, fused_predicted) = if verified {
        let start = fused_run.results[0].as_ref().expect("verified").0 .0;
        let end = fused_run
            .results
            .iter()
            .map(|r| r.as_ref().expect("verified").0 .1)
            .fold(0.0f64, f64::max);
        let scheds: Vec<Option<Schedule>> = fused_run
            .results
            .iter()
            .map(|r| r.as_ref().ok().and_then(|(_, s)| s.clone()))
            .collect();
        (end - start, predicted_from(scheds, topo, Some(machine)))
    } else {
        (0.0, 0.0)
    };

    let (seq_vtime, seq_predicted) = if verified {
        let mut total = 0.0;
        for k in 0..specs.len() {
            let start = seq_run.results[0].as_ref().expect("verified")[k].0;
            let end = seq_run
                .results
                .iter()
                .map(|r| r.as_ref().expect("verified")[k].1)
                .fold(0.0f64, f64::max);
            total += end - start;
        }
        let view = collectives::schedule::WorldView::world(topo);
        let mut predicted = 0.0;
        let world: Vec<usize> = (0..p).collect();
        for s in specs.iter().filter(|s| s.n > 0) {
            if let Ok(w) = collectives::fuse::build_world(s, &view, 8, machine) {
                predicted += cost::predict(&w, topo, &world, machine).unwrap_or(0.0);
            }
        }
        (total, predicted)
    } else {
        (0.0, 0.0)
    };

    FusedReport {
        specs: specs.iter().map(|s| s.label()).collect(),
        p,
        fused_vtime,
        fused_predicted,
        seq_vtime,
        seq_predicted,
        fused_trace: if verified { fused_run.trace.per_op(2) } else { fused_run.trace },
        seq_trace: seq_run.trace,
        verified,
        errors,
    }
}

/// One row of a sweep: a (topology, algorithm) config and its report.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub regions: usize,
    pub ppr: usize,
    pub report: AllgatherReport,
}

/// Sweep `algo` over region counts at fixed ppr — one series of the
/// paper's Figs. 9/10.
pub fn sweep_regions(
    algo: Algorithm,
    region_counts: &[usize],
    ppr: usize,
    machine: &MachineParams,
    n: usize,
) -> Vec<SweepPoint> {
    region_counts
        .iter()
        .map(|&r| {
            let topo = Topology::regions(r, ppr);
            SweepPoint {
                regions: r,
                ppr,
                report: run_allgather(algo, &topo, machine, n),
            }
        })
        .collect()
}

/// Convenience: ensure a report verified, returning a crate error listing
/// the per-rank failures otherwise.
pub fn ensure_verified(report: &AllgatherReport) -> crate::error::Result<()> {
    if report.verified {
        Ok(())
    } else {
        Err(Error::Precondition(format!(
            "{} failed verification: {}",
            report.algorithm,
            report.errors.join("; ")
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bruck_report_on_example_2_1() {
        let topo = Topology::regions(4, 4);
        let r = run_allgather(Algorithm::Bruck, &topo, &MachineParams::lassen(), 1);
        assert!(r.verified, "{:?}", r.errors);
        assert!(r.vtime > 0.0);
        // paper: 4 non-local messages from region-0 ranks
        assert_eq!(r.trace.max_nonlocal_msgs(), 4);
        ensure_verified(&r).unwrap();
    }

    #[test]
    fn schedule_prediction_equals_measured_vtime() {
        // The IR cost model replays the transport's clock algebra, so the
        // predicted time must equal the virtual-time execution exactly.
        let m = MachineParams::lassen();
        let topo = Topology::regions(4, 4);
        for algo in [
            Algorithm::Bruck,
            Algorithm::Pat,
            Algorithm::Ring,
            Algorithm::RecursiveDoubling,
            Algorithm::Dissemination,
            Algorithm::Hierarchical,
            Algorithm::Multilane,
            Algorithm::LocalityBruck,
            Algorithm::ModelTuned,
        ] {
            let r = run_allgather(algo, &topo, &m, 2);
            assert!(r.verified, "{algo}: {:?}", r.errors);
            assert!(
                (r.predicted - r.vtime).abs() < 1e-12,
                "{algo}: predicted {:.6e} vs vtime {:.6e}",
                r.predicted,
                r.vtime
            );
        }
        // the §6 ops predict exactly too
        let ar = run_allreduce("loc-aware", &topo, &m, 2);
        assert!((ar.predicted - ar.vtime).abs() < 1e-12, "allreduce");
        let a2a = run_alltoall("loc-aware", &topo, &m, 2);
        assert!((a2a.predicted - a2a.vtime).abs() < 1e-12, "alltoall");
        for algo in ["ring", "recursive-halving", "pat", "loc-aware", "model-tuned"] {
            let rs = run_reduce_scatter(algo, &topo, &m, 2);
            assert!(rs.verified, "reduce-scatter/{algo}: {:?}", rs.errors);
            assert!(
                (rs.predicted - rs.vtime).abs() < 1e-12,
                "reduce-scatter/{algo}: predicted {:.6e} vs vtime {:.6e}",
                rs.predicted,
                rs.vtime
            );
        }
        for algo in ["rabenseifner", "loc-rabenseifner"] {
            let rab = run_allreduce(algo, &topo, &m, 2);
            assert!(rab.verified, "{algo}: {:?}", rab.errors);
            assert!((rab.predicted - rab.vtime).abs() < 1e-12, "{algo}");
        }
    }

    #[test]
    fn model_tuned_is_never_slower_than_system_default() {
        // The acceptance property on a small fig7-shaped grid: the
        // model-tuned dispatcher picks the measured-fastest candidate at
        // least as often as the MPICH-style static dispatch does — here,
        // strictly: its measured vtime is ≤ system-default's on every
        // configuration (prediction == virtual measurement).
        let m = MachineParams::lassen();
        for ppn in [4usize, 8] {
            for nodes in [2usize, 4, 8] {
                for n in [2usize, 512] {
                    let topo = Topology::regions(nodes, ppn);
                    let tuned = run_allgather(Algorithm::ModelTuned, &topo, &m, n);
                    let sysd = run_allgather(Algorithm::SystemDefault, &topo, &m, n);
                    assert!(tuned.verified && sysd.verified, "{nodes}x{ppn} n={n}");
                    assert!(
                        tuned.vtime <= sysd.vtime + 1e-15,
                        "{nodes}x{ppn} n={n}: model-tuned {:.3e} > system-default {:.3e}",
                        tuned.vtime,
                        sysd.vtime
                    );
                }
            }
        }
    }

    #[test]
    fn loc_bruck_report_on_example_2_1() {
        let topo = Topology::regions(4, 4);
        let r = run_allgather(Algorithm::LocalityBruck, &topo, &MachineParams::lassen(), 1);
        assert!(r.verified, "{:?}", r.errors);
        assert_eq!(r.trace.max_nonlocal_msgs(), 1);
        // paper: 4 non-local values (u32) = 16 bytes vs bruck's 15 values
        assert_eq!(r.trace.max_nonlocal_bytes(), 16);
    }

    #[test]
    fn loc_bruck_models_faster_than_bruck() {
        let topo = Topology::regions(16, 16);
        let m = MachineParams::lassen();
        let std = run_allgather(Algorithm::Bruck, &topo, &m, 2);
        let loc = run_allgather(Algorithm::LocalityBruck, &topo, &m, 2);
        assert!(std.verified && loc.verified);
        assert!(
            loc.vtime < std.vtime,
            "loc {} vs std {}",
            loc.vtime,
            std.vtime
        );
    }

    #[test]
    fn sweep_produces_points() {
        let pts = sweep_regions(
            Algorithm::LocalityBruck,
            &[2, 4, 8],
            4,
            &MachineParams::quartz(),
            2,
        );
        assert_eq!(pts.len(), 3);
        assert!(pts.iter().all(|p| p.report.verified));
        // modeled time grows with region count
        assert!(pts[2].report.vtime > pts[0].report.vtime);
    }

    #[test]
    fn failed_algorithms_are_reported_not_panicked() {
        // recursive doubling on non-power-of-two size fails cleanly
        let topo = Topology::regions(3, 1);
        let r = run_allgather(
            Algorithm::RecursiveDoubling,
            &topo,
            &MachineParams::quartz(),
            1,
        );
        assert!(!r.verified);
        assert!(!r.errors.is_empty());
        assert!(ensure_verified(&r).is_err());
    }

    #[test]
    fn op_repeated_runs_verify_and_measure() {
        let topo = Topology::regions(4, 4);
        let m = MachineParams::lassen();
        let ar = run_allreduce_repeated("loc-aware", &topo, &m, 2, 1, 3);
        assert!(ar.verified, "{:?}", ar.errors);
        assert_eq!(ar.per_iter_vtime.len(), 3);
        for &dt in &ar.per_iter_vtime {
            assert!((dt - ar.per_iter_vtime[0]).abs() < 1e-12, "non-deterministic schedule");
        }
        let a2a = run_alltoall_repeated("bruck", &topo, &m, 2, 1, 3);
        assert!(a2a.verified, "{:?}", a2a.errors);
        assert!(a2a.median_vtime > 0.0);
        // single-shot wrapper reports the identical modeled latency
        let single = run_alltoall("bruck", &topo, &m, 2);
        assert!((single.vtime - a2a.median_vtime).abs() < 1e-12);
        let rs = run_reduce_scatter_repeated("loc-aware", &topo, &m, 2, 1, 3);
        assert!(rs.verified, "{:?}", rs.errors);
        assert_eq!(rs.per_iter_vtime.len(), 3);
        let rs_single = run_reduce_scatter("loc-aware", &topo, &m, 2);
        assert!((rs_single.vtime - rs.median_vtime).abs() < 1e-12);
        // plan-time failures are reported, not panicked
        let bad = run_allreduce("recursive-doubling", &Topology::regions(3, 1), &m, 1);
        assert!(!bad.verified);
        assert!(!bad.errors.is_empty());
        let bad_rs = run_reduce_scatter("recursive-halving", &Topology::regions(3, 1), &m, 1);
        assert!(!bad_rs.verified);
        assert!(!bad_rs.errors.is_empty());
    }

    #[test]
    fn ragged_ops_verify_and_predict_exactly() {
        // The IR cost model is schedule-generic, so the prediction==vtime
        // invariant extends to ragged schedules — including zero-count
        // ranks, which still participate in every round.
        let m = MachineParams::lassen();
        let topo = Topology::regions(4, 4);
        let counts = Counts::new((0..topo.size()).map(|r| r % 5).collect());
        for algo in ["ring", "bruck", "loc-aware", "model-tuned"] {
            let r = run_allgatherv(algo, &topo, &m, &counts);
            assert!(r.verified, "allgatherv/{algo}: {:?}", r.errors);
            assert!(
                (r.predicted - r.vtime).abs() < 1e-12,
                "allgatherv/{algo}: predicted {:.6e} vs vtime {:.6e}",
                r.predicted,
                r.vtime
            );
        }
        for algo in ["ring", "loc-aware", "model-tuned"] {
            let r = run_reduce_scatter_v(algo, &topo, &m, &counts);
            assert!(r.verified, "reduce-scatter-v/{algo}: {:?}", r.errors);
            assert!(
                (r.predicted - r.vtime).abs() < 1e-12,
                "reduce-scatter-v/{algo}: predicted {:.6e} vs vtime {:.6e}",
                r.predicted,
                r.vtime
            );
        }
    }

    #[test]
    fn ragged_repeated_runs_match_single_shot() {
        let m = MachineParams::lassen();
        let topo = Topology::regions(2, 8);
        let counts = Counts::new((0..topo.size()).map(|r| (r * 3) % 7).collect());
        let single = run_allgatherv("loc-aware", &topo, &m, &counts);
        let rep = run_allgatherv_repeated("loc-aware", &topo, &m, &counts, 1, 3);
        assert!(single.verified && rep.verified, "{:?}", rep.errors);
        assert_eq!(rep.per_iter_vtime.len(), 3);
        for &dt in &rep.per_iter_vtime {
            assert!((dt - single.vtime).abs() < 1e-12, "{dt} vs single {}", single.vtime);
        }
        let rs_single = run_reduce_scatter_v("ring", &topo, &m, &counts);
        let rs_rep = run_reduce_scatter_v_repeated("ring", &topo, &m, &counts, 1, 3);
        assert!(rs_single.verified && rs_rep.verified, "{:?}", rs_rep.errors);
        assert!((rs_single.vtime - rs_rep.median_vtime).abs() < 1e-12);
        // unknown algorithms are reported, not panicked
        let bad = run_allgatherv("no-such-algo", &topo, &m, &counts);
        assert!(!bad.verified);
        assert!(!bad.errors.is_empty());
    }

    #[test]
    fn fused_run_accepts_ragged_constituents() {
        use crate::collectives::FuseSpec;
        let topo = Topology::regions(2, 2);
        let m = MachineParams::lassen();
        let counts = Counts::new(vec![3, 0, 2, 1]);
        let specs = vec![
            FuseSpec::ragged(OpKind::Allgatherv, "bruck", counts.clone()),
            FuseSpec::new(OpKind::Allreduce, "loc-aware", 2),
            FuseSpec::ragged(OpKind::ReduceScatterV, "ring", counts),
        ];
        let rep = run_fused(&specs, &topo, &m);
        assert!(rep.verified, "{:?}", rep.errors);
        assert!(
            (rep.fused_predicted - rep.fused_vtime).abs() < 1e-12,
            "predicted {:.6e} vs vtime {:.6e}",
            rep.fused_predicted,
            rep.fused_vtime
        );
        assert!(
            (rep.seq_predicted - rep.seq_vtime).abs() < 1e-12,
            "seq predicted {:.6e} vs vtime {:.6e}",
            rep.seq_predicted,
            rep.seq_vtime
        );
    }

    #[test]
    fn fused_run_matches_prediction_and_beats_sequential() {
        use crate::collectives::FuseSpec;
        let topo = Topology::regions(2, 8);
        let m = MachineParams::lassen();
        let specs = vec![
            FuseSpec::new(OpKind::Allgather, "loc-bruck", 4),
            FuseSpec::new(OpKind::Allreduce, "loc-aware", 2),
        ];
        let rep = run_fused(&specs, &topo, &m);
        assert!(rep.verified, "{:?}", rep.errors);
        // the IR invariant extends to fused schedules: prediction is exact
        assert!(
            (rep.fused_predicted - rep.fused_vtime).abs() < 1e-12,
            "predicted {:.6e} vs vtime {:.6e}",
            rep.fused_predicted,
            rep.fused_vtime
        );
        assert!(
            (rep.seq_predicted - rep.seq_vtime).abs() < 1e-12,
            "seq predicted {:.6e} vs vtime {:.6e}",
            rep.seq_predicted,
            rep.seq_vtime
        );
        // coalescing strictly reduces non-local messages and modeled time
        assert!(rep.fused_trace.max_nonlocal_msgs() < rep.seq_trace.max_nonlocal_msgs());
        assert!(rep.fused_vtime < rep.seq_vtime);
    }

    #[test]
    fn fused_microbatch_allgathers_coalesce_perfectly() {
        use crate::collectives::FuseSpec;
        let topo = Topology::regions(4, 4);
        let m = MachineParams::lassen();
        let specs: Vec<FuseSpec> =
            (0..3).map(|_| FuseSpec::new(OpKind::Allgather, "loc-bruck", 2)).collect();
        let rep = run_fused(&specs, &topo, &m);
        assert!(rep.verified, "{:?}", rep.errors);
        // K identical schedules align slot-for-slot, so every message
        // merges: the fused run carries one constituent's message count.
        let single = run_allgather(Algorithm::LocalityBruck, &topo, &m, 2);
        assert_eq!(rep.fused_trace.max_total_msgs(), single.trace.max_total_msgs());
        assert_eq!(rep.fused_trace.max_nonlocal_msgs(), single.trace.max_nonlocal_msgs());
        assert!(rep.fused_vtime < rep.seq_vtime);
    }

    #[test]
    fn fused_run_handles_zero_length_constituents() {
        use crate::collectives::FuseSpec;
        let topo = Topology::regions(2, 2);
        let specs = vec![
            FuseSpec::new(OpKind::Allgather, "bruck", 2),
            FuseSpec::new(OpKind::Allreduce, "recursive-doubling", 0),
        ];
        let rep = run_fused(&specs, &topo, &MachineParams::lassen());
        assert!(rep.verified, "{:?}", rep.errors);
        assert!(rep.fused_vtime > 0.0);
    }

    #[test]
    fn repeated_run_matches_single_shot_vtime() {
        // The barrier-separated repeated loop must reproduce the single
        // execution's modeled latency on every iteration.
        let m = MachineParams::lassen();
        for algo in [Algorithm::Bruck, Algorithm::LocalityBruck, Algorithm::Ring] {
            let topo = Topology::regions(4, 4);
            let single = run_allgather(algo, &topo, &m, 2);
            let rep = run_allgather_repeated(algo, &topo, &m, 2, 2, 5);
            assert!(single.verified && rep.verified, "{algo}: {:?}", rep.errors);
            assert_eq!(rep.per_iter_vtime.len(), 5);
            for (i, &dt) in rep.per_iter_vtime.iter().enumerate() {
                assert!(
                    (dt - single.vtime).abs() < 1e-12,
                    "{algo} iter {i}: {dt} vs single {}",
                    single.vtime
                );
            }
            // per-op trace matches the single-shot trace
            assert_eq!(rep.trace.max_nonlocal_msgs(), single.trace.max_nonlocal_msgs());
            assert_eq!(rep.trace.total_bytes(), single.trace.total_bytes());
        }
    }
}
