//! The sweep/measurement engine: run any allgather at a given topology and
//! machine model, and report modeled time, wall time, correctness and the
//! locality-classified traffic trace.
//!
//! This is what the figure harness, the examples and the integration tests
//! drive. One call = one data point of a paper figure.

use std::time::Instant;

use crate::collectives::{self, Algorithm};
use crate::comm::{CommWorld, Timing};
use crate::error::Error;
use crate::model::MachineParams;
use crate::topology::Topology;
use crate::trace::TraceSummary;

/// Result of one allgather execution over a world.
#[derive(Debug, Clone)]
pub struct AllgatherReport {
    pub algorithm: Algorithm,
    /// Ranks in the world.
    pub p: usize,
    /// Elements contributed per rank (u32 values, as in the paper's §5).
    pub n: usize,
    /// Modeled completion time (max final virtual clock), seconds.
    pub vtime: f64,
    /// Wall-clock time of the in-process execution, seconds.
    pub wall: f64,
    /// True if every rank produced the expected gathered array.
    pub verified: bool,
    /// Send-side traffic accounting.
    pub trace: TraceSummary,
    /// Per-rank error strings, if the algorithm failed anywhere.
    pub errors: Vec<String>,
}

/// Run `algo` once over `topo` with `n` `u32` values per rank under the
/// virtual-clock transport parameterized by `machine`.
///
/// The paper's measurements use two 4-byte integers per process (§5);
/// `n = 2` reproduces that.
pub fn run_allgather(
    algo: Algorithm,
    topo: &Topology,
    machine: &MachineParams,
    n: usize,
) -> AllgatherReport {
    run_allgather_timed(algo, topo, Timing::Virtual(machine.clone()), n)
}

/// Run `algo` once with an explicit [`Timing`] mode (wall-clock mode is
/// used by the perf benches).
pub fn run_allgather_timed(
    algo: Algorithm,
    topo: &Topology,
    timing: Timing,
    n: usize,
) -> AllgatherReport {
    let p = topo.size();
    let expected: Vec<u32> = (0..p)
        .flat_map(|r| contribution(r, n))
        .collect();
    let start = Instant::now();
    let run = CommWorld::run(topo, timing, |c| {
        let mine = contribution(c.rank(), n);
        collectives::allgather(algo, c, &mine).map(|out| out == expected)
    });
    let wall = start.elapsed().as_secs_f64();
    let mut verified = true;
    let mut errors = Vec::new();
    for (rank, res) in run.results.iter().enumerate() {
        match res {
            Ok(true) => {}
            Ok(false) => {
                verified = false;
                errors.push(format!("rank {rank}: wrong gathered data"));
            }
            Err(e) => {
                verified = false;
                errors.push(format!("rank {rank}: {e}"));
            }
        }
    }
    AllgatherReport {
        algorithm: algo,
        p,
        n,
        vtime: run.max_vtime(),
        wall,
        verified,
        trace: run.trace,
        errors,
    }
}

/// The canonical `u32` contribution used by the sweep engine.
fn contribution(rank: usize, n: usize) -> Vec<u32> {
    (0..n).map(|j| (rank * 131_071 + j) as u32).collect()
}

/// One row of a sweep: a (topology, algorithm) config and its report.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub regions: usize,
    pub ppr: usize,
    pub report: AllgatherReport,
}

/// Sweep `algo` over region counts at fixed ppr — one series of the
/// paper's Figs. 9/10.
pub fn sweep_regions(
    algo: Algorithm,
    region_counts: &[usize],
    ppr: usize,
    machine: &MachineParams,
    n: usize,
) -> Vec<SweepPoint> {
    region_counts
        .iter()
        .map(|&r| {
            let topo = Topology::regions(r, ppr);
            SweepPoint {
                regions: r,
                ppr,
                report: run_allgather(algo, &topo, machine, n),
            }
        })
        .collect()
}

/// Convenience: ensure a report verified, returning a crate error listing
/// the per-rank failures otherwise.
pub fn ensure_verified(report: &AllgatherReport) -> crate::error::Result<()> {
    if report.verified {
        Ok(())
    } else {
        Err(Error::Precondition(format!(
            "{} failed verification: {}",
            report.algorithm,
            report.errors.join("; ")
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bruck_report_on_example_2_1() {
        let topo = Topology::regions(4, 4);
        let r = run_allgather(Algorithm::Bruck, &topo, &MachineParams::lassen(), 1);
        assert!(r.verified, "{:?}", r.errors);
        assert!(r.vtime > 0.0);
        // paper: 4 non-local messages from region-0 ranks
        assert_eq!(r.trace.max_nonlocal_msgs(), 4);
        ensure_verified(&r).unwrap();
    }

    #[test]
    fn loc_bruck_report_on_example_2_1() {
        let topo = Topology::regions(4, 4);
        let r = run_allgather(Algorithm::LocalityBruck, &topo, &MachineParams::lassen(), 1);
        assert!(r.verified, "{:?}", r.errors);
        assert_eq!(r.trace.max_nonlocal_msgs(), 1);
        // paper: 4 non-local values (u32) = 16 bytes vs bruck's 15 values
        assert_eq!(r.trace.max_nonlocal_bytes(), 16);
    }

    #[test]
    fn loc_bruck_models_faster_than_bruck() {
        let topo = Topology::regions(16, 16);
        let m = MachineParams::lassen();
        let std = run_allgather(Algorithm::Bruck, &topo, &m, 2);
        let loc = run_allgather(Algorithm::LocalityBruck, &topo, &m, 2);
        assert!(std.verified && loc.verified);
        assert!(
            loc.vtime < std.vtime,
            "loc {} vs std {}",
            loc.vtime,
            std.vtime
        );
    }

    #[test]
    fn sweep_produces_points() {
        let pts = sweep_regions(
            Algorithm::LocalityBruck,
            &[2, 4, 8],
            4,
            &MachineParams::quartz(),
            2,
        );
        assert_eq!(pts.len(), 3);
        assert!(pts.iter().all(|p| p.report.verified));
        // modeled time grows with region count
        assert!(pts[2].report.vtime > pts[0].report.vtime);
    }

    #[test]
    fn failed_algorithms_are_reported_not_panicked() {
        // recursive doubling on non-power-of-two size fails cleanly
        let topo = Topology::regions(3, 1);
        let r = run_allgather(
            Algorithm::RecursiveDoubling,
            &topo,
            &MachineParams::quartz(),
            1,
        );
        assert!(!r.verified);
        assert!(!r.errors.is_empty());
        assert!(ensure_verified(&r).is_err());
    }
}
