//! The sweep/measurement engine: run any allgather at a given topology and
//! machine model, and report modeled time, wall time, correctness and the
//! locality-classified traffic trace.
//!
//! This is what the figure harness, the examples and the integration tests
//! drive. One [`run_allgather`] call = one data point of a paper figure.
//! [`run_allgather_repeated`] is the benchmark-shaped variant: every rank
//! **plans once** and executes `warmup + iters` times, with a clock-syncing
//! barrier between iterations — the paper's timed loop with communicators
//! created once outside the timed region.

use std::time::Instant;

use crate::collectives::{self, Algorithm, Shape};
use crate::comm::{Comm, CommWorld, Timing};
use crate::error::Error;
use crate::model::MachineParams;
use crate::topology::Topology;
use crate::trace::TraceSummary;
use crate::util::stats;

/// Result of one allgather execution over a world.
#[derive(Debug, Clone)]
pub struct AllgatherReport {
    pub algorithm: Algorithm,
    /// Ranks in the world.
    pub p: usize,
    /// Elements contributed per rank (u32 values, as in the paper's §5).
    pub n: usize,
    /// Modeled completion time (max final virtual clock), seconds.
    pub vtime: f64,
    /// Wall-clock time of the in-process execution, seconds.
    pub wall: f64,
    /// True if every rank produced the expected gathered array.
    pub verified: bool,
    /// Send-side traffic accounting.
    pub trace: TraceSummary,
    /// Per-rank error strings, if the algorithm failed anywhere.
    pub errors: Vec<String>,
}

/// Run `algo` once over `topo` with `n` `u32` values per rank under the
/// virtual-clock transport parameterized by `machine`.
///
/// The paper's measurements use two 4-byte integers per process (§5);
/// `n = 2` reproduces that.
pub fn run_allgather(
    algo: Algorithm,
    topo: &Topology,
    machine: &MachineParams,
    n: usize,
) -> AllgatherReport {
    run_allgather_timed(algo, topo, Timing::Virtual(machine.clone()), n)
}

/// Run `algo` once with an explicit [`Timing`] mode (wall-clock mode is
/// used by the perf benches). Internally plan + execute, like every other
/// call site of the collective layer.
pub fn run_allgather_timed(
    algo: Algorithm,
    topo: &Topology,
    timing: Timing,
    n: usize,
) -> AllgatherReport {
    let p = topo.size();
    let expected: Vec<u32> = (0..p).flat_map(|r| contribution(r, n)).collect();
    let start = Instant::now();
    let run = CommWorld::run(topo, timing, |c| -> crate::error::Result<bool> {
        let mine = contribution(c.rank(), n);
        let mut plan = collectives::plan_allgather::<u32>(algo, c, Shape::elems(n))?;
        let mut out = vec![0u32; n * p];
        plan.execute(&mine, &mut out)?;
        Ok(out == expected)
    });
    let wall = start.elapsed().as_secs_f64();
    let mut verified = true;
    let mut errors = Vec::new();
    for (rank, res) in run.results.iter().enumerate() {
        match res {
            Ok(true) => {}
            Ok(false) => {
                verified = false;
                errors.push(format!("rank {rank}: wrong gathered data"));
            }
            Err(e) => {
                verified = false;
                errors.push(format!("rank {rank}: {e}"));
            }
        }
    }
    AllgatherReport {
        algorithm: algo,
        p,
        n,
        vtime: run.max_vtime(),
        wall,
        verified,
        trace: run.trace,
        errors,
    }
}

/// Result of a plan-once/execute-many run.
#[derive(Debug, Clone)]
pub struct RepeatedReport {
    pub algorithm: Algorithm,
    pub p: usize,
    pub n: usize,
    /// Unmeasured and measured execution counts.
    pub warmup: usize,
    pub iters: usize,
    /// Modeled completion time of each measured execution (barrier-to-end
    /// max clock delta), seconds.
    pub per_iter_vtime: Vec<f64>,
    /// Median of [`RepeatedReport::per_iter_vtime`] — the figure value.
    pub median_vtime: f64,
    /// Wall-clock time of the whole in-process run, seconds.
    pub wall: f64,
    /// True if every execution on every rank produced the expected array.
    pub verified: bool,
    /// Per-execution traffic (total counters divided by `warmup + iters`;
    /// exact because every execution sends the identical schedule).
    pub trace: TraceSummary,
    pub errors: Vec<String>,
}

/// Plan once per rank, execute `warmup + iters` times under virtual
/// timing, measuring each iteration's modeled completion separately.
///
/// A clock-propagating barrier (charging no message costs) separates the
/// iterations, so every measured delta equals the single-shot modeled
/// latency — the paper's timed-loop methodology.
pub fn run_allgather_repeated(
    algo: Algorithm,
    topo: &Topology,
    machine: &MachineParams,
    n: usize,
    warmup: usize,
    iters: usize,
) -> RepeatedReport {
    assert!(iters > 0, "need at least one measured iteration");
    let p = topo.size();
    let total = warmup + iters;
    let expected: Vec<u32> = (0..p).flat_map(|r| contribution(r, n)).collect();
    let start = Instant::now();
    let run = CommWorld::run(topo, Timing::Virtual(machine.clone()), |c: &mut Comm| {
        repeated_worker(c, algo, n, total, &expected)
    });
    let wall = start.elapsed().as_secs_f64();
    let (verified, errors) = collect_errors(&run.results);
    // Iteration i's modeled completion: all ranks start at the same
    // barrier-synced clock; the span is the max end over ranks minus that
    // shared start.
    let mut per_iter_vtime = Vec::with_capacity(iters);
    if verified {
        for i in warmup..total {
            let start_i = run.results[0].as_ref().expect("verified")[i].0;
            let end_i = run
                .results
                .iter()
                .map(|r| r.as_ref().expect("verified")[i].1)
                .fold(0.0f64, f64::max);
            per_iter_vtime.push(end_i - start_i);
        }
    }
    let median_vtime = stats::median(&per_iter_vtime);
    // Only a fully-verified run is guaranteed to have executed the
    // identical schedule `total` times; a mid-loop failure leaves raw
    // (non-divisible) counters.
    let trace = if verified { run.trace.per_op(total as u64) } else { run.trace };
    RepeatedReport {
        algorithm: algo,
        p,
        n,
        warmup,
        iters,
        median_vtime,
        per_iter_vtime,
        wall,
        verified,
        trace,
        errors,
    }
}

/// Per-rank body of [`run_allgather_repeated`]: plan once, then
/// barrier-separated executions recording `(start, end)` clock spans.
fn repeated_worker(
    c: &Comm,
    algo: Algorithm,
    n: usize,
    total: usize,
    expected: &[u32],
) -> crate::error::Result<Vec<(f64, f64)>> {
    let p = c.size();
    let mine = contribution(c.rank(), n);
    let mut plan = collectives::plan_allgather::<u32>(algo, c, Shape::elems(n))?;
    let mut out = vec![0u32; n * p];
    let mut spans = Vec::with_capacity(total);
    for _ in 0..total {
        c.barrier()?; // sync clocks; charges no messages
        let t0 = c.clock();
        plan.execute(&mine, &mut out)?;
        if out != expected {
            return Err(Error::Precondition("wrong gathered data".into()));
        }
        spans.push((t0, c.clock()));
    }
    Ok(spans)
}

fn collect_errors<R>(results: &[crate::error::Result<R>]) -> (bool, Vec<String>) {
    let mut verified = true;
    let mut errors = Vec::new();
    for (rank, res) in results.iter().enumerate() {
        if let Err(e) = res {
            verified = false;
            errors.push(format!("rank {rank}: {e}"));
        }
    }
    (verified, errors)
}

/// The canonical `u32` contribution used by the sweep engine.
fn contribution(rank: usize, n: usize) -> Vec<u32> {
    (0..n).map(|j| (rank * 131_071 + j) as u32).collect()
}

/// One row of a sweep: a (topology, algorithm) config and its report.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub regions: usize,
    pub ppr: usize,
    pub report: AllgatherReport,
}

/// Sweep `algo` over region counts at fixed ppr — one series of the
/// paper's Figs. 9/10.
pub fn sweep_regions(
    algo: Algorithm,
    region_counts: &[usize],
    ppr: usize,
    machine: &MachineParams,
    n: usize,
) -> Vec<SweepPoint> {
    region_counts
        .iter()
        .map(|&r| {
            let topo = Topology::regions(r, ppr);
            SweepPoint {
                regions: r,
                ppr,
                report: run_allgather(algo, &topo, machine, n),
            }
        })
        .collect()
}

/// Convenience: ensure a report verified, returning a crate error listing
/// the per-rank failures otherwise.
pub fn ensure_verified(report: &AllgatherReport) -> crate::error::Result<()> {
    if report.verified {
        Ok(())
    } else {
        Err(Error::Precondition(format!(
            "{} failed verification: {}",
            report.algorithm,
            report.errors.join("; ")
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bruck_report_on_example_2_1() {
        let topo = Topology::regions(4, 4);
        let r = run_allgather(Algorithm::Bruck, &topo, &MachineParams::lassen(), 1);
        assert!(r.verified, "{:?}", r.errors);
        assert!(r.vtime > 0.0);
        // paper: 4 non-local messages from region-0 ranks
        assert_eq!(r.trace.max_nonlocal_msgs(), 4);
        ensure_verified(&r).unwrap();
    }

    #[test]
    fn loc_bruck_report_on_example_2_1() {
        let topo = Topology::regions(4, 4);
        let r = run_allgather(Algorithm::LocalityBruck, &topo, &MachineParams::lassen(), 1);
        assert!(r.verified, "{:?}", r.errors);
        assert_eq!(r.trace.max_nonlocal_msgs(), 1);
        // paper: 4 non-local values (u32) = 16 bytes vs bruck's 15 values
        assert_eq!(r.trace.max_nonlocal_bytes(), 16);
    }

    #[test]
    fn loc_bruck_models_faster_than_bruck() {
        let topo = Topology::regions(16, 16);
        let m = MachineParams::lassen();
        let std = run_allgather(Algorithm::Bruck, &topo, &m, 2);
        let loc = run_allgather(Algorithm::LocalityBruck, &topo, &m, 2);
        assert!(std.verified && loc.verified);
        assert!(
            loc.vtime < std.vtime,
            "loc {} vs std {}",
            loc.vtime,
            std.vtime
        );
    }

    #[test]
    fn sweep_produces_points() {
        let pts = sweep_regions(
            Algorithm::LocalityBruck,
            &[2, 4, 8],
            4,
            &MachineParams::quartz(),
            2,
        );
        assert_eq!(pts.len(), 3);
        assert!(pts.iter().all(|p| p.report.verified));
        // modeled time grows with region count
        assert!(pts[2].report.vtime > pts[0].report.vtime);
    }

    #[test]
    fn failed_algorithms_are_reported_not_panicked() {
        // recursive doubling on non-power-of-two size fails cleanly
        let topo = Topology::regions(3, 1);
        let r = run_allgather(
            Algorithm::RecursiveDoubling,
            &topo,
            &MachineParams::quartz(),
            1,
        );
        assert!(!r.verified);
        assert!(!r.errors.is_empty());
        assert!(ensure_verified(&r).is_err());
    }

    #[test]
    fn repeated_run_matches_single_shot_vtime() {
        // The barrier-separated repeated loop must reproduce the single
        // execution's modeled latency on every iteration.
        let m = MachineParams::lassen();
        for algo in [Algorithm::Bruck, Algorithm::LocalityBruck, Algorithm::Ring] {
            let topo = Topology::regions(4, 4);
            let single = run_allgather(algo, &topo, &m, 2);
            let rep = run_allgather_repeated(algo, &topo, &m, 2, 2, 5);
            assert!(single.verified && rep.verified, "{algo}: {:?}", rep.errors);
            assert_eq!(rep.per_iter_vtime.len(), 5);
            for (i, &dt) in rep.per_iter_vtime.iter().enumerate() {
                assert!(
                    (dt - single.vtime).abs() < 1e-12,
                    "{algo} iter {i}: {dt} vs single {}",
                    single.vtime
                );
            }
            // per-op trace matches the single-shot trace
            assert_eq!(rep.trace.max_nonlocal_msgs(), single.trace.max_nonlocal_msgs());
            assert_eq!(rep.trace.total_bytes(), single.trace.total_bytes());
        }
    }
}
