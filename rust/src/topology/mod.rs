//! Machine topology, rank placement and locality classification.
//!
//! The paper defines a *region* as “a group of cores within which
//! communication is inexpensive” (§2.1): a node on Quartz, a socket on
//! Lassen. A [`Topology`] maps every rank to a physical coordinate
//! (node, socket) under a [`Placement`] strategy and derives
//!
//! * the region of each rank (at the configured [`RegionKind`]),
//! * the *local id* of each rank inside its region (its position in the
//!   region's sorted rank list — what `MPI_Comm_split` would assign), and
//! * the [`Locality`] class of any (src, dst) pair, used by the cost model
//!   and the message traces.

pub mod placement;

pub use placement::Placement;

use crate::error::{Error, Result};

/// Relative location of two communicating ranks, ordered cheap → expensive.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Locality {
    /// Same node, same socket (through cache).
    IntraSocket,
    /// Same node, different socket (through main memory).
    InterSocket,
    /// Different nodes (through the network).
    InterNode,
}

impl Locality {
    /// All classes, cheap → expensive.
    pub const ALL: [Locality; 3] = [
        Locality::IntraSocket,
        Locality::InterSocket,
        Locality::InterNode,
    ];

    /// Short label used in CSV output.
    pub fn label(&self) -> &'static str {
        match self {
            Locality::IntraSocket => "intra-socket",
            Locality::InterSocket => "inter-socket",
            Locality::InterNode => "inter-node",
        }
    }
}

/// What granularity counts as a *region* (the unit of "local").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RegionKind {
    /// Whole node is local (paper's Quartz configuration).
    Node,
    /// Single socket is local (paper's Lassen configuration).
    Socket,
}

/// Physical coordinate of one rank.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Coord {
    pub node: usize,
    pub socket: usize,
}

/// A machine topology: rank → coordinate map plus region bookkeeping.
#[derive(Debug, Clone)]
pub struct Topology {
    coords: Vec<Coord>,
    region_kind: RegionKind,
    /// Region index of each rank (dense, 0-based).
    region_of: Vec<usize>,
    /// Local id of each rank inside its region.
    local_id: Vec<usize>,
    /// Ranks of each region, sorted ascending.
    region_ranks: Vec<Vec<usize>>,
    sockets_per_node: usize,
}

impl Topology {
    /// The simplest topology: `regions` regions of `ppr` ranks each, one
    /// socket per node, block placement. This matches the paper's examples
    /// (“groups of 4 processes are grouped into a region of locality”).
    pub fn regions(regions: usize, ppr: usize) -> Topology {
        Topology::machine(regions, 1, ppr, RegionKind::Node, Placement::Block)
            .expect("regions() arguments are always consistent")
    }

    /// Full machine constructor.
    ///
    /// * `nodes` — number of nodes;
    /// * `sockets_per_node` — sockets per node;
    /// * `cores_per_socket` — ranks per socket (every core runs one rank);
    /// * `region` — what counts as local;
    /// * `placement` — how MPI ranks are laid out over cores.
    pub fn machine(
        nodes: usize,
        sockets_per_node: usize,
        cores_per_socket: usize,
        region: RegionKind,
        placement: Placement,
    ) -> Result<Topology> {
        if nodes == 0 || sockets_per_node == 0 || cores_per_socket == 0 {
            return Err(Error::InvalidTopology(format!(
                "all dimensions must be positive (nodes={nodes}, sockets={sockets_per_node}, cores={cores_per_socket})"
            )));
        }
        let size = nodes * sockets_per_node * cores_per_socket;
        let slots = placement.layout(nodes, sockets_per_node, cores_per_socket);
        debug_assert_eq!(slots.len(), size);
        let coords: Vec<Coord> = slots;

        let nregions_per_node = match region {
            RegionKind::Node => 1,
            RegionKind::Socket => sockets_per_node,
        };
        let region_index = |c: &Coord| match region {
            RegionKind::Node => c.node,
            RegionKind::Socket => c.node * nregions_per_node + c.socket,
        };
        let nregions = nodes * nregions_per_node;
        let region_of: Vec<usize> = coords.iter().map(region_index).collect();
        let mut region_ranks: Vec<Vec<usize>> = vec![Vec::new(); nregions];
        for (rank, &r) in region_of.iter().enumerate() {
            region_ranks[r].push(rank);
        }
        // ranks were pushed in ascending order already
        let mut local_id = vec![0usize; size];
        for ranks in &region_ranks {
            for (i, &rank) in ranks.iter().enumerate() {
                local_id[rank] = i;
            }
        }
        Ok(Topology {
            coords,
            region_kind: region,
            region_of,
            local_id,
            region_ranks,
            sockets_per_node,
        })
    }

    /// Total number of ranks.
    pub fn size(&self) -> usize {
        self.coords.len()
    }

    /// Number of regions.
    pub fn num_regions(&self) -> usize {
        self.region_ranks.len()
    }

    /// Ranks per region, if uniform across regions.
    pub fn procs_per_region(&self) -> Option<usize> {
        let first = self.region_ranks.first()?.len();
        self.region_ranks
            .iter()
            .all(|r| r.len() == first)
            .then_some(first)
    }

    /// Region index of `rank`.
    pub fn region_of(&self, rank: usize) -> usize {
        self.region_of[rank]
    }

    /// Position of `rank` within its region (0-based).
    pub fn local_id(&self, rank: usize) -> usize {
        self.local_id[rank]
    }

    /// All ranks in region `r`, ascending.
    pub fn ranks_in_region(&self, r: usize) -> &[usize] {
        &self.region_ranks[r]
    }

    /// Physical coordinate of a rank.
    pub fn coord(&self, rank: usize) -> Coord {
        self.coords[rank]
    }

    /// The configured region granularity.
    pub fn region_kind(&self) -> RegionKind {
        self.region_kind
    }

    /// Sockets per node of the underlying machine.
    pub fn sockets_per_node(&self) -> usize {
        self.sockets_per_node
    }

    /// Locality class of a message from `a` to `b`.
    pub fn classify(&self, a: usize, b: usize) -> Locality {
        let ca = self.coords[a];
        let cb = self.coords[b];
        if ca.node != cb.node {
            Locality::InterNode
        } else if ca.socket != cb.socket {
            Locality::InterSocket
        } else {
            Locality::IntraSocket
        }
    }

    /// True if `a` and `b` are in the same region (local communication).
    pub fn is_local(&self, a: usize, b: usize) -> bool {
        self.region_of[a] == self.region_of[b]
    }

    /// The permutation mapping *logical* rank order (region-major, i.e.
    /// sorted by (region, local id)) to actual ranks. The locality-aware
    /// algorithms run in logical space, making their non-local traffic
    /// independent of placement (paper §3, last paragraph).
    pub fn logical_order(&self) -> Vec<usize> {
        let mut order = Vec::with_capacity(self.size());
        for ranks in &self.region_ranks {
            order.extend_from_slice(ranks);
        }
        order
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn example_2_1_topology() {
        // 16 processes, groups of 4 per region.
        let t = Topology::regions(4, 4);
        assert_eq!(t.size(), 16);
        assert_eq!(t.num_regions(), 4);
        assert_eq!(t.procs_per_region(), Some(4));
        assert_eq!(t.region_of(0), 0);
        assert_eq!(t.region_of(5), 1);
        assert_eq!(t.region_of(15), 3);
        assert_eq!(t.local_id(5), 1);
        assert_eq!(t.ranks_in_region(2), &[8, 9, 10, 11]);
        assert!(t.is_local(4, 7));
        assert!(!t.is_local(3, 4));
    }

    #[test]
    fn socket_regions_on_two_socket_node() {
        let t = Topology::machine(2, 2, 4, RegionKind::Socket, Placement::Block).unwrap();
        assert_eq!(t.size(), 16);
        assert_eq!(t.num_regions(), 4);
        // ranks 0..4 socket 0 node 0; 4..8 socket 1 node 0
        assert_eq!(t.classify(0, 1), Locality::IntraSocket);
        assert_eq!(t.classify(0, 5), Locality::InterSocket);
        assert_eq!(t.classify(0, 9), Locality::InterNode);
        assert!(t.is_local(0, 3));
        assert!(!t.is_local(0, 4)); // same node, different socket region
    }

    #[test]
    fn node_regions_span_sockets() {
        let t = Topology::machine(2, 2, 4, RegionKind::Node, Placement::Block).unwrap();
        assert_eq!(t.num_regions(), 2);
        assert!(t.is_local(0, 7)); // whole node local
        assert!(!t.is_local(0, 8));
    }

    #[test]
    fn round_robin_placement_classifies_differently() {
        let block = Topology::machine(2, 1, 4, RegionKind::Node, Placement::Block).unwrap();
        let rr = Topology::machine(2, 1, 4, RegionKind::Node, Placement::RoundRobin).unwrap();
        // Under block placement rank 0 and 1 share a node; under round-robin
        // they land on different nodes.
        assert_eq!(block.classify(0, 1), Locality::IntraSocket);
        assert_eq!(rr.classify(0, 1), Locality::InterNode);
        // Region sizes stay uniform either way.
        assert_eq!(rr.procs_per_region(), Some(4));
    }

    #[test]
    fn logical_order_is_permutation() {
        let t = Topology::machine(3, 1, 4, RegionKind::Node, Placement::Random { seed: 9 })
            .unwrap();
        let mut order = t.logical_order();
        // region-major: consecutive logical ids share regions
        for w in order.chunks(4) {
            let r = t.region_of(w[0]);
            assert!(w.iter().all(|&x| t.region_of(x) == r));
        }
        order.sort_unstable();
        assert_eq!(order, (0..12).collect::<Vec<_>>());
    }

    #[test]
    fn zero_dims_rejected() {
        assert!(Topology::machine(0, 1, 1, RegionKind::Node, Placement::Block).is_err());
        assert!(Topology::machine(1, 0, 1, RegionKind::Node, Placement::Block).is_err());
        assert!(Topology::machine(1, 1, 0, RegionKind::Node, Placement::Block).is_err());
    }

    #[test]
    fn local_ids_dense_and_consistent() {
        let t = Topology::machine(4, 2, 2, RegionKind::Socket, Placement::Random { seed: 1 })
            .unwrap();
        for r in 0..t.num_regions() {
            for (i, &rank) in t.ranks_in_region(r).iter().enumerate() {
                assert_eq!(t.local_id(rank), i);
                assert_eq!(t.region_of(rank), r);
            }
        }
    }
}
