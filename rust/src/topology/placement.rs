//! Rank placement strategies.
//!
//! Placement decides which physical core each MPI rank occupies. The paper
//! (§3, final paragraph) observes that the *standard* Bruck algorithm's
//! non-local traffic depends on placement while the locality-aware variant
//! does not; `examples/placement_study.rs` demonstrates exactly that using
//! these strategies.

use super::Coord;
use crate::util::rng::Rng;

/// How ranks are assigned to (node, socket) slots.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// Consecutive ranks fill a socket, then the next socket, then the next
    /// node — the common `--map-by core` default.
    Block,
    /// Ranks are dealt across nodes like cards (`--map-by node`): rank i on
    /// node `i % nodes`.
    RoundRobin,
    /// A random permutation of the block layout, seeded for reproducibility.
    Random { seed: u64 },
}

impl Placement {
    /// Produce the coordinate of every rank, in rank order.
    pub fn layout(
        &self,
        nodes: usize,
        sockets_per_node: usize,
        cores_per_socket: usize,
    ) -> Vec<Coord> {
        let size = nodes * sockets_per_node * cores_per_socket;
        // Enumerate physical slots in block order.
        let mut slots = Vec::with_capacity(size);
        for node in 0..nodes {
            for socket in 0..sockets_per_node {
                for _core in 0..cores_per_socket {
                    slots.push(Coord { node, socket });
                }
            }
        }
        match self {
            Placement::Block => slots,
            Placement::RoundRobin => {
                // rank i -> node i % nodes, filling that node's slots in order.
                let per_node = sockets_per_node * cores_per_socket;
                let mut next_slot = vec![0usize; nodes];
                let mut out = Vec::with_capacity(size);
                let mut node = 0usize;
                for _rank in 0..size {
                    // find next node with a free slot, starting at `node`
                    while next_slot[node] == per_node {
                        node = (node + 1) % nodes;
                    }
                    let slot = next_slot[node];
                    next_slot[node] += 1;
                    let socket = slot / cores_per_socket;
                    out.push(Coord { node, socket });
                    node = (node + 1) % nodes;
                }
                out
            }
            Placement::Random { seed } => {
                let mut rng = Rng::new(*seed);
                rng.shuffle(&mut slots);
                slots
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn count_per_node(coords: &[Coord], nodes: usize) -> Vec<usize> {
        let mut c = vec![0usize; nodes];
        for x in coords {
            c[x.node] += 1;
        }
        c
    }

    #[test]
    fn block_layout_is_contiguous() {
        let l = Placement::Block.layout(2, 2, 2);
        assert_eq!(l.len(), 8);
        assert_eq!(l[0], Coord { node: 0, socket: 0 });
        assert_eq!(l[1], Coord { node: 0, socket: 0 });
        assert_eq!(l[2], Coord { node: 0, socket: 1 });
        assert_eq!(l[4], Coord { node: 1, socket: 0 });
    }

    #[test]
    fn round_robin_alternates_nodes() {
        let l = Placement::RoundRobin.layout(2, 1, 3);
        let nodes: Vec<usize> = l.iter().map(|c| c.node).collect();
        assert_eq!(nodes, vec![0, 1, 0, 1, 0, 1]);
    }

    #[test]
    fn all_layouts_fill_every_slot_exactly_once() {
        for p in [
            Placement::Block,
            Placement::RoundRobin,
            Placement::Random { seed: 5 },
        ] {
            let l = p.layout(3, 2, 4);
            assert_eq!(l.len(), 24);
            assert_eq!(count_per_node(&l, 3), vec![8, 8, 8]);
            // per (node, socket) exactly cores_per_socket ranks
            let mut per = std::collections::HashMap::new();
            for c in &l {
                *per.entry((c.node, c.socket)).or_insert(0usize) += 1;
            }
            assert!(per.values().all(|&v| v == 4));
        }
    }

    #[test]
    fn random_is_deterministic_per_seed() {
        let a = Placement::Random { seed: 1 }.layout(2, 1, 8);
        let b = Placement::Random { seed: 1 }.layout(2, 1, 8);
        let c = Placement::Random { seed: 2 }.layout(2, 1, 8);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }
}
