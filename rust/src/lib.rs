//! # locag — locality-aware collective algorithms
//!
//! A reproduction of *“A Locality-Aware Bruck Allgather”* (Bienz, Gautam,
//! Kharel — EuroMPI/USA'22) as a production-shaped Rust + JAX + Pallas stack.
//!
//! The crate contains every subsystem the paper depends on:
//!
//! * [`comm`] — a thread-based message-passing runtime (“mini-MPI”) with
//!   communicators, tagged matching, non-blocking requests and communicator
//!   splitting, plus a **virtual-clock transport** implementing the paper's
//!   locality-aware postal model (Eq. 2) over real message schedules.
//! * [`topology`] — machine descriptions (nodes / sockets / regions), rank
//!   placement strategies and locality classification.
//! * [`model`] — the postal performance models of §4: Eq. 1 (classic), Eq. 2
//!   (locality-aware), and the closed forms Eq. 3 (Bruck) / Eq. 4
//!   (locality-aware Bruck), with eager/rendezvous protocol switching and
//!   machine presets shaped after the paper's reference [6].
//! * [`collectives`] — an **operation-generic persistent planned-collective
//!   framework** (`MPI_*_init`-style) covering four operations: the
//!   standard Bruck, ring, recursive-doubling, dissemination, hierarchical
//!   (Träff '06), multi-lane (Träff & Hunold '20) and **locality-aware
//!   Bruck** allgathers (incl. multilevel hierarchy and non-power region
//!   counts) plus a system-MPI dispatch baseline; recursive-doubling,
//!   locality-aware regional and any-size Rabenseifner **allreduce**;
//!   pairwise, Bruck and locality-aware **alltoall** (§6 extensions); and
//!   ring, recursive-halving and locality-aware **reduce-scatter** (the
//!   allgather's inverse sibling). Every algorithm plans once per
//!   (communicator, shape) and executes many times with zero setup and
//!   zero allocation, dispatched through pluggable name → algorithm
//!   registries ([`collectives::Registry`],
//!   [`collectives::AllreduceRegistry`],
//!   [`collectives::AlltoallRegistry`],
//!   [`collectives::ReduceScatterRegistry`]) sharing one
//!   [`collectives::CollectivePlan`] substrate — and concurrent plans fuse
//!   into one round-merged, message-coalesced schedule
//!   ([`collectives::fuse`], [`collectives::FusedPlan`]).
//! * [`sim`] — the sweep/measurement engine that runs any algorithm at a
//!   given (p, ppn, data size) and reports virtual time, wall time and a
//!   locality-classified message trace.
//! * [`transport`] — a second, **multi-process** interpreter backend: the
//!   same schedules execute across real OS processes over shared-memory
//!   rings (local class) and Unix sockets (non-local class), bit-identical
//!   to the in-process backend, plus `locag fit` α/β calibration from
//!   ping-pong measurement.
//! * [`trace`] — per-rank message/byte accounting split by locality class.
//! * [`runtime`] — PJRT loading/execution of the AOT artifacts produced by
//!   `python/compile/aot.py` (HLO text; see DESIGN.md).
//! * [`coordinator`] — a tensor-parallel serving coordinator whose hot path
//!   is `PJRT partial forward → allgather(activations) → PJRT final forward`.
//! * [`bench_harness`] — figure regeneration (paper Figs. 3, 7, 8, 9, 10) and
//!   a small wall-clock measurement kit used by `cargo bench`.
//! * [`testkit`] — in-tree property-testing support (offline substitute for
//!   `proptest`; see DESIGN.md §Hardware-Adaptation).
//!
//! ## Quickstart
//!
//! ```
//! use locag::prelude::*;
//!
//! // Example 2.1 of the paper: 16 ranks, 4 ranks per region.
//! let topo = Topology::regions(4, 4);
//! let report = locag::sim::run_allgather(
//!     Algorithm::LocalityBruck,
//!     &topo,
//!     &MachineParams::lassen(),
//!     2, // two u32 values per rank, as in the paper's §5
//! );
//! assert!(report.verified);
//! // The paper's headline: one non-local message per rank (vs 4 for Bruck).
//! assert_eq!(report.trace.max_nonlocal_msgs(), 1);
//! ```
//!
//! ## Persistent plans
//!
//! Hot loops (benchmark figures, the serving coordinator) plan once and
//! execute many times:
//!
//! ```
//! use locag::prelude::*;
//!
//! let topo = Topology::regions(4, 4);
//! let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
//!     // setup — groups, sub-communicators, schedules, tags, scratch —
//!     // happens exactly once here:
//!     let mut plan =
//!         locag::collectives::plan_allgather::<u64>(Algorithm::LocalityBruck, c, Shape::elems(1))
//!             .unwrap();
//!     let mut out = vec![0u64; 16];
//!     for round in 0..100u64 {
//!         // ... and the hot path is pure communication:
//!         plan.execute(&[c.rank() as u64 + round], &mut out).unwrap();
//!     }
//!     out[15]
//! });
//! assert_eq!(run.results[0], 15 + 99);
//! ```
//!
//! The same shape covers the other operations — allreduce and alltoall
//! plans come from their registries by case-insensitive name:
//!
//! ```
//! use locag::prelude::*;
//!
//! let topo = Topology::regions(4, 4);
//! let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
//!     let mut sum = locag::collectives::plan_allreduce::<u64>("loc-aware", c, Shape::elems(2))
//!         .unwrap();
//!     let mut out = vec![0u64; 2];
//!     sum.execute(&[c.rank() as u64, 1], &mut out).unwrap();
//!     out
//! });
//! // elementwise sum over the 16 ranks: [0+1+..+15, 16]
//! assert!(run.results.iter().all(|r| r == &vec![120, 16]));
//! ```
//!
//! ## Fused multi-plan execution
//!
//! Concurrent collectives — the serving loop's allgather and consensus
//! allreduce, or `K` micro-batched allgathers — fuse into **one**
//! round-merged, message-coalesced schedule
//! ([`collectives::plan_fused`], [`collectives::fuse`]): same-round sends
//! to the same peer share a single wire message, paying one postal `α`
//! where sequential execution pays several.
//!
//! ```
//! use locag::collectives::{FuseSpec, OpKind};
//! use locag::prelude::*;
//!
//! let topo = Topology::regions(4, 4);
//! let specs = vec![
//!     FuseSpec::new(OpKind::Allgather, "loc-bruck", 1),
//!     FuseSpec::new(OpKind::Allreduce, "loc-aware", 2),
//! ];
//! let run = CommWorld::run(&topo, Timing::Wallclock, |c| {
//!     let mut plan = locag::collectives::plan_fused::<u64>(c, &specs).unwrap();
//!     let mut gathered = vec![0u64; 16];
//!     let mut sum = vec![0u64; 2];
//!     plan.execute(
//!         &[&[c.rank() as u64], &[1, c.rank() as u64]],
//!         &mut [&mut gathered, &mut sum],
//!     )
//!     .unwrap();
//!     (gathered[15], sum[0])
//! });
//! // both collectives completed through the one fused schedule
//! assert!(run.results.iter().all(|&(g, s)| g == 15 && s == 16));
//! ```

pub mod bench_harness;
pub mod cli;
pub mod collectives;
pub mod comm;
pub mod coordinator;
pub mod error;
pub mod model;
pub mod runtime;
pub mod sim;
pub mod testkit;
pub mod topology;
pub mod trace;
pub mod transport;
pub mod util;

/// Convenient re-exports of the types most programs need.
pub mod prelude {
    pub use crate::collectives::{
        Algorithm, AllgatherPlan, AllreducePlan, AllreduceRegistry, AlltoallPlan,
        AlltoallRegistry, CollectiveAlgorithm, CollectivePlan, FuseSpec, FusedPlan,
        NamedAlgorithm, OpKind, ReduceScatterPlan, ReduceScatterRegistry, Registry, Shape,
    };
    pub use crate::comm::{Comm, CommWorld, Timing};
    pub use crate::model::{MachineParams, Protocol};
    pub use crate::sim::{
        run_allgather, run_allreduce, run_alltoall, run_fused, run_reduce_scatter,
        AllgatherReport, FusedReport, OpReport,
    };
    pub use crate::topology::{Locality, Placement, Topology};
    pub use crate::trace::TraceSummary;
}
