//! IR-derived cost models: evaluate any communication [`Schedule`] against
//! [`MachineParams`] **without executing it**.
//!
//! The paper's §4 analysis has two ingredients, and both fall out of the
//! schedule IR mechanically:
//!
//! 1. **Static traffic counts** ([`counts`]): walking one rank's schedule
//!    and classifying every send by the locality of its (src, dst) pair
//!    reproduces the paper's per-process message/byte accounting — e.g.
//!    standard Bruck's `⌈log₂ p⌉` non-local messages of `m−1` total values
//!    vs the locality-aware variant's `⌈log_pℓ(r)⌉` messages of `≈ b/pℓ`
//!    bytes (§2.1, §4). These are the *same* quantities the runtime tracer
//!    measures, and `tests/collective_conformance.rs` asserts schedule ⇔
//!    execution can never drift.
//! 2. **Predicted completion time** ([`predict`]): replaying the postal
//!    clock algebra of the virtual transport (paper Eq. 2: a send charges
//!    `α_c + β_c·s` on the sender; a receive synchronizes the receiver to
//!    the sender's post-charge stamp) over all ranks' schedules yields the
//!    max final clock — the locality-split α-β composition of Bienz et
//!    al.'s node-aware models, evaluated on the *real* message schedule
//!    rather than a closed form. For schedules produced by the builders in
//!    [`crate::collectives`], `predict` equals the virtual-time execution
//!    exactly (asserted in `tests/model_vs_sim.rs`).
//!
//! The model-tuned dispatcher ([`crate::collectives::model_tuned`]) is the
//! consumer that closes the loop: it builds candidate schedules, scores
//! them here, and plans the cheapest.

use crate::collectives::schedule::{replay_world, ReplayHandler, Schedule, Slice};
use crate::error::Result;
use crate::model::MachineParams;
use crate::topology::Topology;
use crate::trace::RankTrace;

/// Whole-schedule-set evaluation: predicted completion plus per-rank
/// traffic, the static twin of a measured
/// [`crate::trace::TraceSummary`].
#[derive(Debug, Clone)]
pub struct CostReport {
    /// Modeled completion time (max final virtual clock), seconds.
    pub predicted: f64,
    /// Per-rank send-side accounting derived from the schedules.
    pub per_rank: Vec<RankTrace>,
}

impl CostReport {
    /// Max non-local messages sent by any rank (the paper's headline).
    pub fn max_nonlocal_msgs(&self) -> u64 {
        self.per_rank.iter().map(|t| t.nonlocal_msgs).max().unwrap_or(0)
    }

    /// Max non-local bytes sent by any rank.
    pub fn max_nonlocal_bytes(&self) -> u64 {
        self.per_rank.iter().map(|t| t.nonlocal_bytes).max().unwrap_or(0)
    }
}

/// Static per-rank traffic of one schedule: every send (including the send
/// half of a `SendRecv`) classified by the locality of its rank pair.
/// Self-sends are local memcpys and are not counted — exactly like the
/// runtime tracer.
pub fn counts(sched: &Schedule, rank: usize, topo: &Topology, world_of: &[usize]) -> RankTrace {
    let mut t = RankTrace::default();
    for step in sched.steps() {
        if let Some((to, len, pad)) = step.send_part() {
            if to == rank {
                continue;
            }
            let (a, b) = (world_of[rank], world_of[to]);
            t.record(topo.classify(a, b), topo.is_local(a, b), sched.wire_bytes(len, pad));
        }
    }
    t
}

/// The postal-clock replay handler: sends charge `α_c + β_c·bytes` on the
/// sender and stamp the message with the post-charge clock; receives
/// synchronize the receiver to the stamp. One of the two meanings of the
/// shared mailbox-replay walker
/// ([`crate::collectives::schedule`]'s `replay_world` — the other is
/// fuse's framing verifier).
struct PostalReplay<'a> {
    scheds: &'a [Schedule],
    topo: &'a Topology,
    world_of: &'a [usize],
    machine: &'a MachineParams,
    clock: Vec<f64>,
}

impl ReplayHandler for PostalReplay<'_> {
    type Msg = f64;

    fn on_send(&mut self, rank: usize, to: usize, src: &Slice, _tag: u64, pad: usize) -> f64 {
        let (a, b) = (self.world_of[rank], self.world_of[to]);
        if a != b {
            // self-sends are local memcpys: never charged
            let bytes = self.scheds[rank].wire_bytes(src.len, pad);
            self.clock[rank] += self.machine.cost(self.topo.classify(a, b), bytes);
        }
        self.clock[rank]
    }

    fn on_recv(
        &mut self,
        rank: usize,
        _from: usize,
        _dst: &Slice,
        _tag: u64,
        _pad: usize,
        stamp: f64,
    ) -> Result<()> {
        self.clock[rank] = self.clock[rank].max(stamp);
        Ok(())
    }
}

/// Predicted completion time of a whole world of schedules (one per rank,
/// indexed by rank) under the locality-split postal model.
///
/// This replays the virtual-clock transport symbolically: a discrete-event
/// pass in which each rank advances through its schedule, sends charge
/// `α_c + β_c·bytes` and stamp the message with the post-charge clock,
/// and receives block until the matching stamp is available, then take the
/// max. Local steps (copy/reduce/rotate) are free, matching the
/// transport. Errors if the schedules deadlock (a receive whose matching
/// send never happens) — which a correct builder never produces. The
/// walking itself (cursors, FIFO matching) is the shared
/// `replay_world` pass, so this model and fuse's framing verifier can
/// never drift in matching discipline.
pub fn predict(
    scheds: &[Schedule],
    topo: &Topology,
    world_of: &[usize],
    machine: &MachineParams,
) -> Result<f64> {
    let mut h = PostalReplay { scheds, topo, world_of, machine, clock: vec![0.0; scheds.len()] };
    replay_world(scheds, "schedule set", &mut h)?;
    Ok(h.clock.iter().copied().fold(0.0, f64::max))
}

/// [`counts`] for every rank plus [`predict`]: the full static evaluation
/// of a schedule set.
pub fn evaluate(
    scheds: &[Schedule],
    topo: &Topology,
    world_of: &[usize],
    machine: &MachineParams,
) -> Result<CostReport> {
    let per_rank = (0..scheds.len())
        .map(|r| counts(&scheds[r], r, topo, world_of))
        .collect();
    Ok(CostReport { predicted: predict(scheds, topo, world_of, machine)?, per_rank })
}

/// Fused-vs-sequential evaluation of one fusion: the fused world's cost
/// next to the cost of executing the constituent plans back to back.
/// Sequential cost follows the barrier-separated methodology of the
/// repeated runners: predicted completions add, per-rank traffic merges.
#[derive(Debug, Clone)]
pub struct FusionReport {
    /// Evaluation of the fused schedules.
    pub fused: CostReport,
    /// Evaluation of the constituents executed sequentially.
    pub sequential: CostReport,
}

impl FusionReport {
    /// Predicted completion-time saving of fusion, seconds (negative if
    /// fusion is predicted slower).
    pub fn predicted_saving(&self) -> f64 {
        self.sequential.predicted - self.fused.predicted
    }

    /// Non-local wire messages removed by coalescing, summed over ranks.
    pub fn nonlocal_msgs_saved(&self) -> i64 {
        let seq: u64 = self.sequential.per_rank.iter().map(|t| t.nonlocal_msgs).sum();
        let fus: u64 = self.fused.per_rank.iter().map(|t| t.nonlocal_msgs).sum();
        seq as i64 - fus as i64
    }
}

/// Evaluate fused schedules against their constituents executed
/// sequentially. `constituent_worlds[k]` holds all ranks' schedules of
/// constituent `k` (what [`crate::collectives::fuse::build_world`]
/// returns).
pub fn evaluate_fusion(
    fused: &[Schedule],
    constituent_worlds: &[Vec<Schedule>],
    topo: &Topology,
    world_of: &[usize],
    machine: &MachineParams,
) -> Result<FusionReport> {
    let fused_rep = evaluate(fused, topo, world_of, machine)?;
    let mut per_rank = vec![RankTrace::default(); fused.len()];
    let mut predicted = 0.0;
    for world in constituent_worlds {
        let rep = evaluate(world, topo, world_of, machine)?;
        predicted += rep.predicted;
        for (acc, t) in per_rank.iter_mut().zip(&rep.per_rank) {
            acc.merge(t);
        }
    }
    Ok(FusionReport { fused: fused_rep, sequential: CostReport { predicted, per_rank } })
}

/// Build every rank's schedule for one allgather algorithm — the
/// whole-world view the dispatcher and `locag explain` score.
pub fn allgather_schedules(
    algo: crate::collectives::Algorithm,
    topo: &Topology,
    n: usize,
    elem_bytes: usize,
) -> Result<Vec<Schedule>> {
    let view = crate::collectives::schedule::WorldView::world(topo);
    (0..topo.size())
        .map(|r| crate::collectives::schedule::build_allgather(algo, &view, r, n, elem_bytes))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::Algorithm;
    use crate::model::closed_form::ModelConfig;

    #[test]
    fn bruck_prediction_matches_eq3() {
        // predict() over the Bruck schedules must equal the closed form on
        // block placement (every Bruck exchange is non-local at 4x4).
        let topo = Topology::regions(4, 4);
        let m = MachineParams::lassen();
        let scheds = allgather_schedules(Algorithm::Bruck, &topo, 2, 4).unwrap();
        let world: Vec<usize> = (0..16).collect();
        let t = predict(&scheds, &topo, &world, &m).unwrap();
        let cf = ModelConfig::lassen().bruck(16, 8);
        assert!((t - cf).abs() < 1e-12, "predict {t:.3e} vs closed form {cf:.3e}");
    }

    #[test]
    fn counts_match_paper_example_2_1() {
        let topo = Topology::regions(4, 4);
        let world: Vec<usize> = (0..16).collect();
        let scheds = allgather_schedules(Algorithm::LocalityBruck, &topo, 1, 4).unwrap();
        for (r, s) in scheds.iter().enumerate() {
            let t = counts(s, r, &topo, &world);
            if r % 4 == 0 {
                assert_eq!(t.nonlocal_msgs, 0, "local rank 0 idles (rank {r})");
            } else {
                assert_eq!(t.nonlocal_msgs, 1, "rank {r}");
                assert_eq!(t.nonlocal_bytes, 16, "rank {r}: 4 u32 values");
            }
        }
    }

    #[test]
    fn loc_bruck_predicts_cheaper_than_bruck() {
        let topo = Topology::regions(16, 16);
        let m = MachineParams::lassen();
        let world: Vec<usize> = (0..topo.size()).collect();
        let std =
            predict(&allgather_schedules(Algorithm::Bruck, &topo, 2, 4).unwrap(), &topo, &world, &m)
                .unwrap();
        let loc = predict(
            &allgather_schedules(Algorithm::LocalityBruck, &topo, 2, 4).unwrap(),
            &topo,
            &world,
            &m,
        )
        .unwrap();
        assert!(loc < std, "loc {loc:.3e} !< std {std:.3e}");
    }

    #[test]
    fn deadlocked_schedule_reports_error() {
        use crate::collectives::schedule::{ScheduleBuilder, Slice};
        use crate::collectives::OpKind;
        let topo = Topology::regions(1, 2);
        let world = vec![0usize, 1];
        let mut sb = ScheduleBuilder::new("bad");
        let tag = sb.tag();
        sb.recv(1, Slice::output(0, 1), tag, 0);
        let bad = sb.finish(OpKind::Allgather, 2, 1, 8, "bad");
        let mut sb = ScheduleBuilder::new("idle");
        sb.tag();
        let idle = sb.finish(OpKind::Allgather, 2, 1, 8, "idle");
        let err = predict(&[bad, idle], &topo, &world, &MachineParams::lassen());
        assert!(err.is_err());
    }

    #[test]
    fn fusion_evaluation_reports_savings() {
        use crate::collectives::fuse::{build_world, fuse_world, FuseSpec};
        use crate::collectives::schedule::WorldView;
        use crate::collectives::OpKind;
        let topo = Topology::regions(2, 8);
        let view = WorldView::world(&topo);
        let m = MachineParams::lassen();
        let specs = vec![
            FuseSpec::new(OpKind::Allgather, "loc-bruck", 4),
            FuseSpec::new(OpKind::Allreduce, "loc-aware", 2),
        ];
        let (fused, _) = fuse_world(&specs, &view, 8, &m).unwrap();
        let worlds: Vec<Vec<Schedule>> =
            specs.iter().map(|s| build_world(s, &view, 8, &m).unwrap()).collect();
        let rep = evaluate_fusion(&fused, &worlds, &topo, &view.world_of, &m).unwrap();
        // coalescing merges the aligned non-local exchanges: strictly
        // fewer non-local messages and a predicted-time saving
        assert!(rep.nonlocal_msgs_saved() > 0, "{}", rep.nonlocal_msgs_saved());
        assert!(rep.fused.max_nonlocal_msgs() < rep.sequential.max_nonlocal_msgs());
        assert!(rep.predicted_saving() > 0.0, "{}", rep.predicted_saving());
    }

    #[test]
    fn evaluate_bundles_counts_and_prediction() {
        let topo = Topology::regions(2, 2);
        let world: Vec<usize> = (0..4).collect();
        let scheds = allgather_schedules(Algorithm::Ring, &topo, 2, 4).unwrap();
        let rep = evaluate(&scheds, &topo, &world, &MachineParams::quartz()).unwrap();
        assert_eq!(rep.per_rank.len(), 4);
        assert!(rep.predicted > 0.0);
        // ring: every rank sends p-1 = 3 messages
        for t in &rep.per_rank {
            assert_eq!(t.total_msgs(), 3);
        }
        assert!(rep.max_nonlocal_msgs() > 0);
        assert!(rep.max_nonlocal_bytes() > 0);
    }
}
