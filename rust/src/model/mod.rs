//! Performance models (paper §4).
//!
//! * [`params`] — per-locality-class postal parameters (α latency, β
//!   inverse bandwidth) with eager/rendezvous protocol switching and the
//!   Lassen/Quartz presets used throughout the evaluation.
//! * [`closed_form`] — the paper's closed-form costs: Eq. 3 (standard
//!   Bruck), Eq. 4 (locality-aware Bruck), plus the analogous forms for the
//!   baselines (ring, recursive doubling, hierarchical, multi-lane) needed
//!   to regenerate Figures 7 and 8.
//! * [`cost`] — **IR-derived models**: evaluate any communication
//!   [`crate::collectives::Schedule`] against [`MachineParams`] to get a
//!   predicted completion time and per-class traffic counts without
//!   executing — the engine behind the `model-tuned` dispatcher and the
//!   `predicted` column of the figures.
//!
//! The same [`MachineParams`] also parameterize the virtual-clock transport
//! in [`crate::comm`], so closed forms, schedule-derived predictions and
//! "measured" virtual-time executions share one source of truth (asserted
//! to agree in `rust/tests/model_vs_sim.rs`).

pub mod closed_form;
pub mod cost;
pub mod params;

pub use params::{ClassParams, MachineParams, Postal, Protocol};
