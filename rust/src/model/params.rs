//! Postal-model parameters per locality class, with protocol switching.
//!
//! The paper's Eq. 1 models a message of `s` bytes as `α + β·s`. Eq. 2
//! refines this with separate `(α_ℓ, β_ℓ)` for local traffic. Real MPI
//! implementations additionally switch from the *eager* protocol to the
//! *rendezvous* protocol at a size threshold (8192 B in the paper's Fig. 7
//! caption), so every class carries two parameter pairs.
//!
//! The preset values below are calibrated to reproduce the *ordering and
//! ratios* of the paper's Fig. 3 ping-pong measurements (intra-socket ≪
//! inter-socket ≪ inter-node) and the modeled curves of Figs. 7–8. The
//! absolute microseconds of the LLNL testbeds are not reproducible off-site;
//! see DESIGN.md §Hardware-Adaptation.

use std::fmt::Write as _;
use std::path::Path;

use crate::error::{Error, Result};
use crate::topology::Locality;
use crate::util::json::Json;

/// Schema tag of fitted-parameter files written by `locag fit`.
pub const PARAMS_SCHEMA: &str = "locag-params-v1";

/// Which message protocol a transfer uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Protocol {
    /// Small messages: sent immediately, copied at the receiver.
    Eager,
    /// Large messages: handshake first, then zero-copy transfer.
    Rendezvous,
}

/// One (α, β) pair: `cost(s) = alpha + beta * s` seconds for `s` bytes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Postal {
    /// Per-message latency in seconds.
    pub alpha: f64,
    /// Per-byte cost in seconds (inverse bandwidth).
    pub beta: f64,
}

impl Postal {
    /// Cost of one `bytes`-byte message.
    pub fn cost(&self, bytes: usize) -> f64 {
        self.alpha + self.beta * bytes as f64
    }
}

/// Parameters of one locality class: eager + rendezvous pairs and the
/// switch-over threshold.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClassParams {
    pub eager: Postal,
    pub rendezvous: Postal,
    /// Messages of at least this many bytes use the rendezvous protocol.
    pub eager_cutoff: usize,
}

impl ClassParams {
    /// Protocol used for a message of `bytes` bytes.
    pub fn protocol(&self, bytes: usize) -> Protocol {
        if bytes >= self.eager_cutoff {
            Protocol::Rendezvous
        } else {
            Protocol::Eager
        }
    }

    /// Postal pair for a message of `bytes` bytes.
    pub fn postal(&self, bytes: usize) -> Postal {
        match self.protocol(bytes) {
            Protocol::Eager => self.eager,
            Protocol::Rendezvous => self.rendezvous,
        }
    }

    /// Modeled cost of one message of `bytes` bytes.
    pub fn cost(&self, bytes: usize) -> f64 {
        self.postal(bytes).cost(bytes)
    }
}

/// Full machine model: one [`ClassParams`] per locality class.
#[derive(Debug, Clone, PartialEq)]
pub struct MachineParams {
    pub name: &'static str,
    pub intra_socket: ClassParams,
    pub inter_socket: ClassParams,
    pub inter_node: ClassParams,
}

/// The paper's (and MPICH's) default eager→rendezvous threshold.
pub const DEFAULT_EAGER_CUTOFF: usize = 8192;

impl MachineParams {
    /// Parameters of one locality class.
    pub fn class(&self, loc: Locality) -> &ClassParams {
        match loc {
            Locality::IntraSocket => &self.intra_socket,
            Locality::InterSocket => &self.inter_socket,
            Locality::InterNode => &self.inter_node,
        }
    }

    /// Modeled cost of one message of `bytes` bytes in class `loc`.
    pub fn cost(&self, loc: Locality, bytes: usize) -> f64 {
        self.class(loc).cost(bytes)
    }

    /// Lassen-shaped preset (Power9 + InfiniBand EDR, Spectrum MPI). The
    /// paper treats a *socket* as the local region on this machine because
    /// inter-socket traffic is nearly as expensive as the network (§2.1).
    pub fn lassen() -> MachineParams {
        MachineParams {
            name: "lassen",
            intra_socket: ClassParams {
                eager: Postal { alpha: 3.5e-7, beta: 2.2e-11 },
                rendezvous: Postal { alpha: 1.1e-6, beta: 9.0e-12 },
                eager_cutoff: DEFAULT_EAGER_CUTOFF,
            },
            inter_socket: ClassParams {
                eager: Postal { alpha: 9.0e-7, beta: 6.5e-11 },
                rendezvous: Postal { alpha: 2.6e-6, beta: 2.4e-11 },
                eager_cutoff: DEFAULT_EAGER_CUTOFF,
            },
            inter_node: ClassParams {
                eager: Postal { alpha: 1.9e-6, beta: 1.6e-10 },
                rendezvous: Postal { alpha: 5.4e-6, beta: 8.0e-11 },
                eager_cutoff: DEFAULT_EAGER_CUTOFF,
            },
        }
    }

    /// Quartz-shaped preset (Intel Xeon E5 + Omni-Path, MVAPICH2). Here the
    /// whole node is the local region: inter-socket costs sit much closer
    /// to intra-socket than to the network.
    pub fn quartz() -> MachineParams {
        MachineParams {
            name: "quartz",
            intra_socket: ClassParams {
                eager: Postal { alpha: 4.0e-7, beta: 2.5e-11 },
                rendezvous: Postal { alpha: 1.2e-6, beta: 1.0e-11 },
                eager_cutoff: DEFAULT_EAGER_CUTOFF,
            },
            inter_socket: ClassParams {
                eager: Postal { alpha: 6.0e-7, beta: 4.0e-11 },
                rendezvous: Postal { alpha: 1.6e-6, beta: 1.8e-11 },
                eager_cutoff: DEFAULT_EAGER_CUTOFF,
            },
            inter_node: ClassParams {
                eager: Postal { alpha: 1.5e-6, beta: 2.4e-10 },
                rendezvous: Postal { alpha: 4.2e-6, beta: 8.5e-11 },
                eager_cutoff: DEFAULT_EAGER_CUTOFF,
            },
        }
    }

    /// Serialize to the `locag-params-v1` JSON format `locag fit` writes.
    pub fn to_json(&self) -> String {
        fn postal(out: &mut String, p: &Postal) {
            let _ = write!(out, "{{\"alpha\": {:e}, \"beta\": {:e}}}", p.alpha, p.beta);
        }
        let mut out = String::new();
        let _ = write!(out, "{{\n  \"schema\": \"{PARAMS_SCHEMA}\",\n");
        let _ = write!(out, "  \"name\": \"{}\",\n  \"classes\": {{\n", self.name);
        for (i, loc) in Locality::ALL.iter().enumerate() {
            let c = self.class(*loc);
            let _ = write!(out, "    \"{}\": {{\"eager\": ", loc.label());
            postal(&mut out, &c.eager);
            out.push_str(", \"rendezvous\": ");
            postal(&mut out, &c.rendezvous);
            let _ = write!(out, ", \"eager_cutoff\": {}}}", c.eager_cutoff);
            out.push_str(if i + 1 < Locality::ALL.len() { ",\n" } else { "\n" });
        }
        out.push_str("  }\n}\n");
        out
    }

    /// Parse a `locag-params-v1` document.
    pub fn from_json_str(doc: &str) -> Result<MachineParams> {
        let bad = |what: &str| Error::Precondition(format!("params file: {what}"));
        let j = Json::parse(doc).map_err(|e| bad(&format!("not valid JSON ({e})")))?;
        match j.get("schema").and_then(Json::as_str) {
            Some(PARAMS_SCHEMA) => {}
            other => return Err(bad(&format!("schema {other:?}, expected {PARAMS_SCHEMA}"))),
        }
        let name = j.get("name").and_then(Json::as_str).unwrap_or("fitted");
        // Names are &'static str throughout the model layer; a loaded file
        // can carry an arbitrary name, so intern unknown ones. Params files
        // load O(1) times per process, so the leak is bounded.
        let name: &'static str = match name {
            "lassen" => "lassen",
            "quartz" => "quartz",
            "uniform" => "uniform",
            "fitted" => "fitted",
            other => Box::leak(other.to_string().into_boxed_str()),
        };
        let classes = j.get("classes").ok_or_else(|| bad("missing 'classes'"))?;
        let postal = |v: &Json, what: &str| -> Result<Postal> {
            let f = |k: &str| {
                v.get(k)
                    .and_then(Json::as_f64)
                    .ok_or_else(|| bad(&format!("missing {what}.{k}")))
            };
            Ok(Postal { alpha: f("alpha")?, beta: f("beta")? })
        };
        let class = |loc: Locality| -> Result<ClassParams> {
            let label = loc.label();
            let c = classes
                .get(label)
                .ok_or_else(|| bad(&format!("missing class '{label}'")))?;
            Ok(ClassParams {
                eager: postal(
                    c.get("eager").ok_or_else(|| bad(&format!("missing {label}.eager")))?,
                    label,
                )?,
                rendezvous: postal(
                    c.get("rendezvous")
                        .ok_or_else(|| bad(&format!("missing {label}.rendezvous")))?,
                    label,
                )?,
                eager_cutoff: c
                    .get("eager_cutoff")
                    .and_then(Json::as_usize)
                    .unwrap_or(DEFAULT_EAGER_CUTOFF),
            })
        };
        Ok(MachineParams {
            name,
            intra_socket: class(Locality::IntraSocket)?,
            inter_socket: class(Locality::InterSocket)?,
            inter_node: class(Locality::InterNode)?,
        })
    }

    /// Load fitted parameters from a file written by `locag fit`.
    pub fn load(path: &Path) -> Result<MachineParams> {
        let doc = std::fs::read_to_string(path)?;
        MachineParams::from_json_str(&doc)
    }

    /// Resolve a `--machine` argument: a preset name (case-insensitive) or
    /// a path to a fitted-params file.
    pub fn by_name_or_path(s: &str) -> Result<MachineParams> {
        match s.to_ascii_lowercase().as_str() {
            "lassen" => return Ok(MachineParams::lassen()),
            "quartz" => return Ok(MachineParams::quartz()),
            _ => {}
        }
        let path = Path::new(s);
        if path.is_file() {
            return MachineParams::load(path);
        }
        Err(Error::Precondition(format!(
            "unknown machine '{s}' (valid: lassen, quartz, or a path to a \
             locag-params-v1 file from `locag fit`)"
        )))
    }

    /// A uniform machine where every class costs the same — useful for
    /// testing that locality-aware algorithms degrade gracefully to the
    /// classic model (Eq. 2 collapses to Eq. 1).
    pub fn uniform(alpha: f64, beta: f64) -> MachineParams {
        let c = ClassParams {
            eager: Postal { alpha, beta },
            rendezvous: Postal { alpha, beta },
            eager_cutoff: DEFAULT_EAGER_CUTOFF,
        };
        MachineParams {
            name: "uniform",
            intra_socket: c,
            inter_socket: c,
            inter_node: c,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn postal_cost_is_affine() {
        let p = Postal { alpha: 1e-6, beta: 1e-9 };
        assert_eq!(p.cost(0), 1e-6);
        assert!((p.cost(1000) - 2e-6).abs() < 1e-15);
    }

    #[test]
    fn protocol_switches_at_cutoff() {
        let c = MachineParams::lassen().inter_node;
        assert_eq!(c.protocol(0), Protocol::Eager);
        assert_eq!(c.protocol(8191), Protocol::Eager);
        assert_eq!(c.protocol(8192), Protocol::Rendezvous);
    }

    #[test]
    fn locality_ordering_holds_for_presets() {
        // The essential property for the paper's result: each class is
        // strictly cheaper than the next for small messages.
        for m in [MachineParams::lassen(), MachineParams::quartz()] {
            for s in [8usize, 64, 1024, 65536] {
                let intra = m.cost(Locality::IntraSocket, s);
                let inter_s = m.cost(Locality::InterSocket, s);
                let inter_n = m.cost(Locality::InterNode, s);
                assert!(intra < inter_s, "{} @{}", m.name, s);
                assert!(inter_s < inter_n, "{} @{}", m.name, s);
            }
        }
    }

    #[test]
    fn uniform_machine_is_uniform() {
        let m = MachineParams::uniform(1e-6, 1e-9);
        for s in [1usize, 100, 100000] {
            let a = m.cost(Locality::IntraSocket, s);
            let b = m.cost(Locality::InterNode, s);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn params_json_roundtrips() {
        for m in [MachineParams::lassen(), MachineParams::quartz()] {
            let doc = m.to_json();
            let back = MachineParams::from_json_str(&doc).unwrap();
            assert_eq!(back, m);
        }
    }

    #[test]
    fn from_json_rejects_bad_documents() {
        assert!(MachineParams::from_json_str("not json").is_err());
        assert!(MachineParams::from_json_str("{\"schema\": \"other\"}").is_err());
        // Valid schema but missing classes.
        let doc = format!("{{\"schema\": \"{PARAMS_SCHEMA}\", \"name\": \"x\"}}");
        assert!(MachineParams::from_json_str(&doc).is_err());
    }

    #[test]
    fn by_name_or_path_resolves_presets_and_files() {
        assert_eq!(MachineParams::by_name_or_path("LASSEN").unwrap().name, "lassen");
        assert_eq!(MachineParams::by_name_or_path("quartz").unwrap().name, "quartz");
        assert!(MachineParams::by_name_or_path("no-such-machine").is_err());

        let dir = std::env::temp_dir().join(format!("locag-params-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fitted.json");
        std::fs::write(&path, MachineParams::lassen().to_json()).unwrap();
        let m = MachineParams::by_name_or_path(path.to_str().unwrap()).unwrap();
        assert_eq!(m.intra_socket, MachineParams::lassen().intra_socket);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rendezvous_beats_eager_for_large_messages() {
        for m in [MachineParams::lassen(), MachineParams::quartz()] {
            for loc in Locality::ALL {
                let c = m.class(loc);
                // At 1 MiB the rendezvous line must be below the eager line
                // extrapolation (higher bandwidth).
                let s = 1 << 20;
                assert!(c.rendezvous.cost(s) < c.eager.cost(s));
            }
        }
    }
}
