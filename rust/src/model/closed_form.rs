//! Closed-form cost models for every allgather in the paper (§4).
//!
//! These regenerate the paper's Figures 7 and 8. Each form sums per-step
//! message costs with the protocol (eager/rendezvous) chosen per message
//! size, exactly as the paper's Fig. 7 caption describes. The virtual-clock
//! executions in [`crate::sim`] must agree with these forms on
//! power-of-two configurations — asserted in `rust/tests/model_vs_sim.rs`.
//!
//! Conventions: `p` ranks, `ppr` ranks per region, `r = p / ppr` regions,
//! `n` = **bytes contributed per rank** (the paper's `m/p` values ×
//! datatype size). Returned times are seconds.

use super::params::MachineParams;
use crate::topology::Locality;
use crate::util::{ilog2_ceil, ilog_ceil, ipow};

/// Binds a machine to a choice of which locality classes represent "local"
/// and "non-local" traffic for the closed forms.
///
/// On Quartz the region is a node: local ≈ intra-socket (dominant on-node
/// path), non-local = inter-node. On Lassen the region is a socket and only
/// one socket per node is used in the paper's measurements, so local =
/// intra-socket and non-local = inter-node as well.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub machine: MachineParams,
    pub local: Locality,
    pub nonlocal: Locality,
}

impl ModelConfig {
    /// Paper's Quartz configuration (node regions).
    pub fn quartz() -> ModelConfig {
        ModelConfig {
            machine: MachineParams::quartz(),
            local: Locality::IntraSocket,
            nonlocal: Locality::InterNode,
        }
    }

    /// Paper's Lassen configuration (socket regions, one socket per node).
    pub fn lassen() -> ModelConfig {
        ModelConfig {
            machine: MachineParams::lassen(),
            local: Locality::IntraSocket,
            nonlocal: Locality::InterNode,
        }
    }

    fn c_local(&self, bytes: usize) -> f64 {
        self.machine.cost(self.local, bytes)
    }

    fn c_nonlocal(&self, bytes: usize) -> f64 {
        self.machine.cost(self.nonlocal, bytes)
    }

    /// Eq. 3 — standard Bruck allgather: `⌈log2(p)⌉` non-local messages
    /// (worst rank), step `i` carrying `min(2^i, p−2^i)·n` bytes.
    pub fn bruck(&self, p: usize, n: usize) -> f64 {
        assert!(p > 0);
        let mut t = 0.0;
        for i in 0..ilog2_ceil(p) {
            // step i sends min(2^i, p - 2^i) blocks (partial final step for
            // non-power-of-two p)
            let blk = (1usize << i).min(p - (1usize << i));
            t += self.c_nonlocal(blk * n);
        }
        t
    }

    /// Ring allgather: `p−1` steps; the critical path crosses a region
    /// boundary every step, so each step is charged at non-local cost.
    pub fn ring(&self, p: usize, n: usize) -> f64 {
        p.saturating_sub(1) as f64 * self.c_nonlocal(n)
    }

    /// Recursive-doubling allgather: step `i` exchanges `2^i·n` bytes with
    /// the rank at XOR-distance `2^i`; under block placement the first
    /// `log2(ppr)` steps stay inside the region.
    pub fn recursive_doubling(&self, p: usize, ppr: usize, n: usize) -> f64 {
        assert!(p.is_power_of_two(), "recursive doubling requires power-of-two p");
        let mut t = 0.0;
        for i in 0..ilog2_ceil(p) {
            let dist = 1usize << i;
            let bytes = dist * n;
            if dist < ppr {
                t += self.c_local(bytes);
            } else {
                t += self.c_nonlocal(bytes);
            }
        }
        t
    }

    /// Hierarchical allgather (Träff '06): flat gather to the region master
    /// (serialized at the master), Bruck among the `r` masters, then a
    /// binomial-tree broadcast of the full array inside each region.
    pub fn hierarchical(&self, p: usize, ppr: usize, n: usize) -> f64 {
        assert!(p % ppr == 0);
        let r = p / ppr;
        let mut t = 0.0;
        // gather: master receives ppr-1 local messages of n bytes, serialized.
        t += (ppr - 1) as f64 * self.c_local(n);
        // bruck among masters, each contributing ppr*n bytes
        t += self.bruck(r, ppr * n);
        // local broadcast of the whole p*n array, binomial tree
        t += ilog2_ceil(ppr) as f64 * self.c_local(p * n);
        t
    }

    /// Multi-lane allgather (Träff & Hunold '20): lane `ℓ` (one per local
    /// rank) runs an inter-node Bruck over its own `n` bytes, then a local
    /// allgather of the `r·n`-byte lane results.
    pub fn multilane(&self, p: usize, ppr: usize, n: usize) -> f64 {
        assert!(p % ppr == 0);
        let r = p / ppr;
        let mut t = 0.0;
        // inter-node bruck per lane
        t += self.bruck(r, n);
        // local allgather (bruck) of r*n-byte blocks
        for j in 0..ilog2_ceil(ppr) {
            let blk = (1usize << j).min(ppr - (1usize << j));
            t += self.c_local(blk * r * n);
        }
        t
    }

    /// Eq. 4 — locality-aware Bruck (Algorithm 2): a local Bruck, then
    /// `⌈log_ppr(r)⌉` single non-local exchanges each followed by a local
    /// Bruck of the received group.
    pub fn loc_bruck(&self, p: usize, ppr: usize, n: usize) -> f64 {
        assert!(p % ppr == 0, "p must be divisible by ppr");
        let r = p / ppr;
        let mut t = 0.0;
        // phase 1: local allgather of the initial n-byte blocks
        for j in 0..ilog2_ceil(ppr) {
            let blk = (1usize << j).min(ppr - (1usize << j));
            t += self.c_local(blk * n);
        }
        if r == 1 {
            return t;
        }
        let steps = ilog_ceil(ppr.max(2), r);
        for i in 0..steps {
            // one non-local exchange of the current group (ppr^(i+1) ranks' data)
            let group_bytes = ipow(ppr, i + 1).min(p) * n;
            t += self.c_nonlocal(group_bytes);
            // local allgather of the received group blocks
            for j in 0..ilog2_ceil(ppr) {
                let blk = (1usize << j).min(ppr - (1usize << j));
                t += self.c_local(blk * group_bytes);
            }
        }
        t
    }

    /// The system-MPI baseline selection (Thakur et al. [19], as shipped in
    /// MPICH/MVAPICH2): recursive doubling for small power-of-two, Bruck
    /// for small non-power-of-two, ring for large totals.
    pub fn system_default(&self, p: usize, ppr: usize, n: usize) -> f64 {
        let total = p * n;
        const LONG_MSG: usize = 81920; // MPICH MPIR_ALLGATHER_LONG_MSG default
        if total < LONG_MSG {
            if p.is_power_of_two() {
                self.recursive_doubling(p, ppr, n)
            } else {
                self.bruck(p, n)
            }
        } else {
            self.ring(p, n)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig::lassen()
    }

    #[test]
    fn bruck_matches_eq3_without_protocol_split() {
        // With a uniform single-protocol machine, Eq. 3 is exactly
        // log2(p)·α + (p-1)/p·b·β.
        let m = ModelConfig {
            machine: MachineParams::uniform(1e-6, 1e-9),
            local: Locality::IntraSocket,
            nonlocal: Locality::InterNode,
        };
        let (p, n) = (16usize, 8usize);
        let t = m.bruck(p, n);
        let b = (p * n) as f64;
        let expect = 4.0 * 1e-6 + (b - b / p as f64) * 1e-9;
        assert!((t - expect).abs() < 1e-12, "{t} vs {expect}");
    }

    #[test]
    fn loc_bruck_example_2_1_message_counts() {
        // For p=16, ppr=4 the locality-aware algorithm does exactly one
        // non-local exchange; with α-dominated small data the cost is close
        // to 1 non-local α + 3 local-bruck phases... sanity: fewer non-local
        // α's than standard bruck.
        let c = cfg();
        let t_std = c.bruck(16, 8);
        let t_loc = c.loc_bruck(16, 4, 8);
        assert!(t_loc < t_std, "loc {t_loc} vs std {t_std}");
    }

    #[test]
    fn loc_bruck_single_region_is_pure_local() {
        let c = cfg();
        let t = c.loc_bruck(8, 8, 16);
        // equals a local bruck of 8 ranks
        let m_local = ModelConfig {
            machine: c.machine.clone(),
            local: c.local,
            nonlocal: c.local,
        };
        assert!((t - m_local.bruck(8, 16)).abs() < 1e-12);
    }

    #[test]
    fn improvement_grows_with_ppr() {
        // Paper's core claim: improvements are amplified as ppr increases.
        // The paper's modeled curves use a continuous log_pℓ(r); the
        // implementation pays ⌈log_pℓ(r)⌉ steps, so we assert monotonicity
        // along configurations where r is an exact power of ppr (no ceiling
        // slack) and improvement (> 1×) everywhere ppr ≥ 4.
        let c = cfg();
        let n = 8;
        let r = 64usize; // regions
        for ppr in [4usize, 8, 16, 32, 64] {
            let p = r * ppr;
            let ratio = c.bruck(p, n) / c.loc_bruck(p, ppr, n);
            assert!(ratio > 1.0, "ppr={ppr}: ratio {ratio} <= 1");
        }
        let mut prev_ratio = 0.0;
        for ppr in [4usize, 8, 64] {
            // 64 = 4^3 = 8^2 = 64^1: aligned cases
            let p = r * ppr;
            let ratio = c.bruck(p, n) / c.loc_bruck(p, ppr, n);
            assert!(ratio > prev_ratio, "ppr={ppr}: {ratio} <= {prev_ratio}");
            prev_ratio = ratio;
        }
    }

    #[test]
    fn recursive_doubling_cheaper_than_bruck_with_locality() {
        // First log2(ppr) steps are local under block placement, so RD is
        // cheaper than all-non-local Bruck on a locality machine.
        let c = cfg();
        assert!(c.recursive_doubling(64, 8, 8) < c.bruck(64, 8));
    }

    #[test]
    fn ring_scales_linearly() {
        let c = cfg();
        let t1 = c.ring(64, 8);
        let t2 = c.ring(128, 8);
        assert!(t2 > 1.9 * t1 && t2 < 2.1 * t1);
    }

    #[test]
    fn system_default_picks_ring_for_large() {
        let c = cfg();
        let small = c.system_default(16, 4, 8);
        assert_eq!(small, c.recursive_doubling(16, 4, 8));
        let large_n = 100_000; // total far above LONG_MSG
        let large = c.system_default(16, 4, large_n);
        assert_eq!(large, c.ring(16, large_n));
        // non power of two small -> bruck
        let np = c.system_default(12, 4, 8);
        assert_eq!(np, c.bruck(12, 8));
    }

    #[test]
    fn hierarchical_and_multilane_between_bruck_and_loc() {
        // On a strongly locality-skewed machine with many ranks per region,
        // the paper's ordering for small data: loc-bruck < hierarchical,
        // multilane < standard bruck (Figs. 9-10 for large PPN).
        let c = cfg();
        let (p, ppr, n) = (1024usize, 16usize, 8usize);
        let std = c.bruck(p, n);
        let hier = c.hierarchical(p, ppr, n);
        let lane = c.multilane(p, ppr, n);
        let loc = c.loc_bruck(p, ppr, n);
        assert!(loc < std);
        assert!(loc < hier);
        assert!(loc < lane);
    }

    #[test]
    fn non_power_region_counts_supported() {
        let c = cfg();
        // r = 6 regions with ppr = 4: ceil(log_4 6) = 2 non-local steps.
        let t = c.loc_bruck(24, 4, 8);
        assert!(t > 0.0);
        // more regions with same ppr costs at least as much
        assert!(c.loc_bruck(64, 4, 8) >= t * 0.5);
    }
}
