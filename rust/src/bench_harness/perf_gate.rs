//! The perf-regression gate over `locag bench --json` artifacts.
//!
//! `locag bench` emits a `locag-bench-v1` JSON document of
//! [`BenchRow`]s — one per `(op, algorithm, topology, payload)` point,
//! carrying the modeled completion (`vtime`), the IR-predicted completion
//! (`predicted`) and the wall time of the in-process run. CI uploads the
//! document as the `bench-json` artifact on every run; this module is the
//! read side: [`parse`] round-trips the artifact through the in-tree JSON
//! parser and [`compare`] diffs a fresh run against a baseline, flagging
//! any row whose `vtime` or `predicted` grew by more than the threshold.
//!
//! Only the *deterministic* metrics gate: `vtime` and `predicted` are pure
//! functions of (schedule, machine model), identical on every honest run
//! of the same source — so a flagged regression is a real scheduling or
//! cost-model change, never CI noise. `wall` and `wall_proc` are recorded
//! for trend curiosity and deliberately ignored by the gate; a baseline
//! and a current run may disagree about which rows carry `wall_proc` at
//! all (one ran `--backend proc`, the other didn't) and the comparison
//! must neither error nor gate on the difference.
//!
//! The CI step is reproducible locally:
//! `locag bench --json NEW.json --compare OLD.json` exits non-zero iff
//! [`CompareReport::passed`] is false.

use crate::error::{Error, Result};
use crate::util::json::Json;

/// One measured point of a bench artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchRow {
    /// Operation name (`allgather`, `reduce-scatter`, …).
    pub op: String,
    /// Registry name of the algorithm.
    pub algo: String,
    pub regions: usize,
    /// Ranks per region.
    pub ppr: usize,
    /// World size.
    pub p: usize,
    /// Elements per rank.
    pub n: usize,
    /// Modeled completion time, seconds (deterministic; gated).
    pub vtime: f64,
    /// IR-predicted completion time, seconds (deterministic; gated).
    pub predicted: f64,
    /// Wall-clock seconds of the in-process run (noisy; not gated).
    pub wall: f64,
    /// Wall-clock seconds of the multi-process run, when the row was also
    /// executed with `--backend proc` (noisy; not gated). Absent from
    /// artifacts produced before the proc backend existed — [`parse`]
    /// accepts both shapes, and [`compare`] never looks at it.
    pub wall_proc: Option<f64>,
    pub verified: bool,
}

impl BenchRow {
    /// The identity two artifacts are joined on.
    pub fn key(&self) -> String {
        format!("{}/{} {}x{} n={}", self.op, self.algo, self.regions, self.ppr, self.n)
    }

    fn to_json(&self) -> String {
        // `wall_proc` is emitted only when measured, so sim-only artifacts
        // stay byte-compatible with pre-proc-backend baselines.
        let wall_proc = match self.wall_proc {
            Some(w) => format!("\"wall_proc\": {w:e}, "),
            None => String::new(),
        };
        format!(
            concat!(
                "    {{\"op\": \"{}\", \"algo\": \"{}\", \"regions\": {}, ",
                "\"ppr\": {}, \"p\": {}, \"n\": {}, \"vtime\": {:e}, ",
                "\"predicted\": {:e}, \"wall\": {:e}, {}\"verified\": {}}}"
            ),
            self.op,
            self.algo,
            self.regions,
            self.ppr,
            self.p,
            self.n,
            self.vtime,
            self.predicted,
            self.wall,
            wall_proc,
            self.verified
        )
    }
}

/// A parsed `locag-bench-v1` artifact: the machine model the rows were
/// measured against plus the rows themselves. The machine participates in
/// [`compare`]'s validity check — vtimes from different cost models must
/// never be diffed.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchDoc {
    pub machine: String,
    pub rows: Vec<BenchRow>,
}

/// Render the full `locag-bench-v1` document.
pub fn render(machine: &str, rows: &[BenchRow]) -> String {
    let body: Vec<String> = rows.iter().map(BenchRow::to_json).collect();
    format!(
        "{{\n  \"schema\": \"locag-bench-v1\",\n  \"machine\": \"{machine}\",\n  \"rows\": [\n{}\n  ]\n}}\n",
        body.join(",\n")
    )
}

/// Parse a `locag-bench-v1` document.
pub fn parse(doc: &str) -> Result<BenchDoc> {
    let bad = |what: &str| Error::Precondition(format!("bench JSON: {what}"));
    let j = Json::parse(doc).map_err(|e| bad(&e))?;
    match j.get("schema").and_then(Json::as_str) {
        Some("locag-bench-v1") => {}
        other => return Err(bad(&format!("unknown schema {other:?}"))),
    }
    let machine = j
        .get("machine")
        .and_then(Json::as_str)
        .ok_or_else(|| bad("missing machine"))?
        .to_string();
    let rows = j.get("rows").and_then(Json::as_arr).ok_or_else(|| bad("missing rows"))?;
    let mut out = Vec::with_capacity(rows.len());
    for row in rows {
        let field_str = |k: &str| {
            row.get(k).and_then(Json::as_str).map(str::to_string).ok_or_else(|| {
                bad(&format!("row missing string field '{k}'"))
            })
        };
        let field_usize = |k: &str| {
            row.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| bad(&format!("row missing integer field '{k}'")))
        };
        let field_f64 = |k: &str| {
            row.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| bad(&format!("row missing number field '{k}'")))
        };
        out.push(BenchRow {
            op: field_str("op")?,
            algo: field_str("algo")?,
            regions: field_usize("regions")?,
            ppr: field_usize("ppr")?,
            p: field_usize("p")?,
            n: field_usize("n")?,
            vtime: field_f64("vtime")?,
            predicted: field_f64("predicted")?,
            wall: field_f64("wall")?,
            wall_proc: row.get("wall_proc").and_then(Json::as_f64),
            verified: matches!(row.get("verified"), Some(Json::Bool(true))),
        });
    }
    Ok(BenchDoc { machine, rows: out })
}

/// One gated metric that grew past the threshold.
#[derive(Debug, Clone)]
pub struct Regression {
    /// [`BenchRow::key`] of the offending row.
    pub key: String,
    /// Which metric regressed (`"vtime"` or `"predicted"`).
    pub metric: &'static str,
    pub baseline: f64,
    pub current: f64,
}

impl Regression {
    /// Fractional growth over the baseline.
    pub fn growth(&self) -> f64 {
        (self.current - self.baseline) / self.baseline
    }
}

/// Outcome of one baseline-vs-current comparison.
#[derive(Debug, Clone)]
pub struct CompareReport {
    /// The gate's threshold (fractional growth, e.g. `0.2` for 20%).
    pub threshold: f64,
    /// Rows present on both sides and diffed.
    pub compared: usize,
    /// Baseline rows with no current counterpart (removed points; warned,
    /// not failed).
    pub only_baseline: usize,
    /// Current rows with no baseline counterpart (new points; warned, not
    /// failed — a fresh algorithm must not fail the gate that predates it).
    pub only_current: usize,
    /// Every gated metric that grew past the threshold.
    pub regressions: Vec<Regression>,
}

impl CompareReport {
    /// True iff no gated metric regressed past the threshold.
    pub fn passed(&self) -> bool {
        self.regressions.is_empty()
    }

    /// Human-readable summary (regressions first, then the join stats).
    pub fn table(&self) -> String {
        let mut out = String::new();
        for r in &self.regressions {
            out.push_str(&format!(
                "REGRESSION {:<40} {:<9} {:.3e} -> {:.3e} (+{:.1}% > {:.0}%)\n",
                r.key,
                r.metric,
                r.baseline,
                r.current,
                r.growth() * 100.0,
                self.threshold * 100.0
            ));
        }
        out.push_str(&format!(
            "perf gate: {} row(s) compared, {} regression(s); {} baseline-only, {} new\n",
            self.compared,
            self.regressions.len(),
            self.only_baseline,
            self.only_current
        ));
        out
    }
}

/// Diff `current` against `baseline`: a row regresses when a gated metric
/// (`vtime`, `predicted`) grows by more than `threshold` (fractional, e.g.
/// `0.2`) over the baseline row with the same [`BenchRow::key`]. Rows on
/// only one side are counted but never fail the gate; non-positive
/// baseline values are skipped (no meaningful ratio). Wall columns are
/// never consulted: rows whose `wall_proc` is present on one side and
/// absent on the other (only one run used `--backend proc`) still join on
/// their key and gate only on the deterministic metrics. Errors when the
/// two docs were measured against different machine models — those vtimes
/// are not comparable (regenerate the baseline with the matching
/// `--machine`).
pub fn compare_docs(
    baseline: &BenchDoc,
    current: &BenchDoc,
    threshold: f64,
) -> Result<CompareReport> {
    if baseline.machine != current.machine {
        return Err(Error::Precondition(format!(
            "perf baselines are machine-specific: baseline was measured on '{}' but this run \
             uses '{}' — regenerate the baseline with the matching --machine",
            baseline.machine, current.machine
        )));
    }
    Ok(compare(&baseline.rows, &current.rows, threshold))
}

/// Row-level comparison (see [`compare_docs`], which also checks machine
/// compatibility).
pub fn compare(baseline: &[BenchRow], current: &[BenchRow], threshold: f64) -> CompareReport {
    let mut regressions = Vec::new();
    let mut compared = 0usize;
    let mut only_current = 0usize;
    for cur in current {
        let key = cur.key();
        match baseline.iter().find(|b| b.key() == key) {
            None => only_current += 1,
            Some(base) => {
                compared += 1;
                let gated = [
                    ("vtime", base.vtime, cur.vtime),
                    ("predicted", base.predicted, cur.predicted),
                ];
                for (metric, old, new) in gated {
                    if old > 0.0 && new > old * (1.0 + threshold) {
                        regressions.push(Regression {
                            key: key.clone(),
                            metric,
                            baseline: old,
                            current: new,
                        });
                    }
                }
            }
        }
    }
    let only_baseline =
        baseline.iter().filter(|b| !current.iter().any(|c| c.key() == b.key())).count();
    CompareReport { threshold, compared, only_baseline, only_current, regressions }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(op: &str, algo: &str, vtime: f64) -> BenchRow {
        BenchRow {
            op: op.to_string(),
            algo: algo.to_string(),
            regions: 4,
            ppr: 4,
            p: 16,
            n: 2,
            vtime,
            predicted: vtime,
            wall: 0.01,
            wall_proc: None,
            verified: true,
        }
    }

    #[test]
    fn render_parse_roundtrip() {
        let rows = vec![row("allgather", "bruck", 1.5e-5), row("reduce-scatter", "ring", 3.25e-4)];
        let doc = render("lassen", &rows);
        let back = parse(&doc).unwrap();
        assert_eq!(back.machine, "lassen");
        assert_eq!(back.rows, rows);
    }

    #[test]
    fn parse_rejects_malformed_documents() {
        assert!(parse("{}").is_err());
        assert!(parse("{\"schema\": \"other\", \"rows\": []}").is_err());
        let no_machine = "{\"schema\": \"locag-bench-v1\", \"rows\": []}";
        assert!(parse(no_machine).is_err());
        let missing_field = "{\"schema\": \"locag-bench-v1\", \"machine\": \"lassen\", \
                             \"rows\": [{\"op\": \"allgather\"}]}";
        assert!(parse(missing_field).is_err());
        assert!(parse("not json").is_err());
    }

    #[test]
    fn cross_machine_baselines_are_rejected() {
        // vtimes from different cost models must never be diffed: the
        // doc-level gate refuses instead of reporting nonsense.
        let rows = vec![row("allgather", "bruck", 1e-5)];
        let lassen = BenchDoc { machine: "lassen".to_string(), rows: rows.clone() };
        let quartz = BenchDoc { machine: "quartz".to_string(), rows: rows.clone() };
        let err = compare_docs(&lassen, &quartz, 0.2).unwrap_err().to_string();
        assert!(err.contains("machine-specific"), "{err}");
        assert!(compare_docs(&lassen, &lassen.clone(), 0.2).unwrap().passed());
    }

    #[test]
    fn identical_runs_pass_the_gate() {
        let rows = vec![row("allgather", "bruck", 1e-5), row("allgather", "ring", 2e-5)];
        let rep = compare(&rows, &rows, 0.2);
        assert!(rep.passed());
        assert_eq!(rep.compared, 2);
        assert_eq!(rep.only_baseline + rep.only_current, 0);
        assert!(rep.table().contains("0 regression(s)"));
    }

    #[test]
    fn gate_fires_on_artificially_slowed_rows() {
        // The acceptance scenario: the same schedule made 2x slower (as an
        // artificially degraded build would be) must fail the 20% gate.
        let baseline = vec![row("allgather", "bruck", 1e-5), row("allgather", "ring", 2e-5)];
        let mut slowed = baseline.clone();
        slowed[1].vtime *= 2.0;
        slowed[1].predicted *= 2.0;
        let rep = compare(&baseline, &slowed, 0.2);
        assert!(!rep.passed());
        assert_eq!(rep.regressions.len(), 2); // vtime + predicted
        assert_eq!(rep.regressions[0].key, "allgather/ring 4x4 n=2");
        assert!(rep.regressions[0].growth() > 0.99);
        assert!(rep.table().contains("REGRESSION"));
    }

    #[test]
    fn within_threshold_growth_passes() {
        let baseline = vec![row("allgather", "bruck", 1.0e-5)];
        let mut current = baseline.clone();
        current[0].vtime = 1.19e-5; // +19% < 20%
        current[0].predicted = 1.19e-5;
        assert!(compare(&baseline, &current, 0.2).passed());
        current[0].vtime = 1.21e-5; // +21% > 20%
        assert!(!compare(&baseline, &current, 0.2).passed());
    }

    #[test]
    fn new_and_removed_rows_warn_but_never_fail() {
        // A new algorithm (this PR adds reduce-scatter rows) must not fail
        // the gate against a baseline that predates it.
        let baseline = vec![row("allgather", "bruck", 1e-5), row("allgather", "old", 1e-5)];
        let current = vec![row("allgather", "bruck", 1e-5), row("reduce-scatter", "ring", 9e9)];
        let rep = compare(&baseline, &current, 0.2);
        assert!(rep.passed());
        assert_eq!(rep.compared, 1);
        assert_eq!(rep.only_baseline, 1);
        assert_eq!(rep.only_current, 1);
    }

    #[test]
    fn wall_time_is_not_gated() {
        let baseline = vec![row("allgather", "bruck", 1e-5)];
        let mut current = baseline.clone();
        current[0].wall *= 100.0; // wall noise must never fail the gate
        current[0].wall_proc = Some(9e9); // neither must proc wall time
        assert!(compare(&baseline, &current, 0.2).passed());
    }

    #[test]
    fn mixed_wall_proc_presence_joins_cleanly_in_both_directions() {
        // Direction 1: the baseline predates the proc backend (no
        // wall_proc anywhere), the current run measured it. Direction 2:
        // the baseline has proc walls, the current run skipped --backend
        // proc. Both must join on the row key, gate only vtime/predicted,
        // and never error — even when the same artifact mixes rows with
        // and without the column.
        let mut with_proc = vec![row("allgather", "bruck", 1e-5), row("allgather", "ring", 2e-5)];
        with_proc[0].wall_proc = Some(3.5e-3); // mixed presence within one doc
        let without_proc = vec![row("allgather", "bruck", 1e-5), row("allgather", "ring", 2e-5)];

        let old_doc = BenchDoc { machine: "lassen".to_string(), rows: without_proc.clone() };
        let new_doc = BenchDoc { machine: "lassen".to_string(), rows: with_proc.clone() };

        let rep = compare_docs(&old_doc, &new_doc, 0.2).unwrap();
        assert!(rep.passed());
        assert_eq!(rep.compared, 2);
        assert_eq!(rep.only_baseline + rep.only_current, 0);

        let rep = compare_docs(&new_doc, &old_doc, 0.2).unwrap();
        assert!(rep.passed());
        assert_eq!(rep.compared, 2);

        // A genuine vtime regression still fires regardless of which side
        // carries the proc column.
        let mut slowed = without_proc.clone();
        slowed[0].vtime *= 2.0;
        assert!(!compare(&with_proc, &slowed, 0.2).passed());

        // And the serialized forms of both docs survive the round trip, so
        // the CI artifact diff sees the same rows this test does.
        let rt = parse(&render("lassen", &with_proc)).unwrap();
        assert_eq!(rt.rows, with_proc);
        assert!(compare_docs(&rt, &old_doc, 0.2).unwrap().passed());
    }

    #[test]
    fn wall_proc_column_is_optional_and_roundtrips() {
        let mut rows = vec![row("allgather", "bruck", 1e-5)];
        rows[0].wall_proc = Some(2.5e-3);
        let doc = render("lassen", &rows);
        assert!(doc.contains("\"wall_proc\""), "{doc}");
        assert_eq!(parse(&doc).unwrap().rows, rows);
        // Sim-only rows omit the column entirely, and artifacts written
        // before the proc backend existed still parse (and compare: the
        // machine+key join never touches wall columns).
        let old = render("lassen", &[row("allgather", "bruck", 1e-5)]);
        assert!(!old.contains("wall_proc"), "{old}");
        assert_eq!(parse(&old).unwrap().rows[0].wall_proc, None);
    }
}
