//! Regenerate every figure of the paper's evaluation as CSV + ASCII plot.
//!
//! | figure | content | source of numbers |
//! |---|---|---|
//! | 3 | ping-pong cost by locality class (Lassen) | machine model presets (calibrated to the paper's shape; see DESIGN.md) |
//! | 7 | modeled Bruck vs loc-aware vs node count, per PPN | closed forms (Eq. 3/4 with protocol switching) |
//! | 8 | modeled cost vs data size at 1024×16 | closed forms |
//! | 9 | "measured" cost on Quartz (node regions) | virtual-time execution of the real implementations |
//! | 10 | "measured" cost on Lassen (socket regions) | virtual-time execution |
//!
//! The virtual-time "measured" runs execute the actual `Isend/Irecv`
//! message schedules of every algorithm over the thread mailboxes and
//! accumulate the locality-aware postal model along real dependencies —
//! the off-testbed stand-in for the LLNL machines (DESIGN.md
//! §Hardware-Adaptation).

use crate::collectives::{Algorithm, OpKind};
use crate::csv_row;
use crate::error::Result;
use crate::model::closed_form::ModelConfig;
use crate::model::MachineParams;
use crate::sim;
use crate::topology::{Locality, Topology};
use crate::transport::{pool_median_wall, Backend, ProcConfig, ProcJob, ProcPool};
use crate::util::csv::CsvWriter;
use crate::util::fmt::{ascii_plot, Series};

/// A generated figure: CSV rows already written; series kept for plotting.
pub struct Figure {
    pub title: String,
    /// (series label, points (x, y)).
    pub series: Vec<(String, Vec<(f64, f64)>)>,
}

impl Figure {
    /// Render the ASCII preview.
    pub fn plot(&self) -> String {
        let series: Vec<Series<'_>> = self
            .series
            .iter()
            .map(|(label, pts)| Series { label, points: pts })
            .collect();
        ascii_plot(&self.title, &series, 72, 20)
    }
}

/// Figure 3: ping-pong cost per locality class, 1 B – 1 MiB.
pub fn fig3(out_csv: &str) -> Result<Figure> {
    let m = MachineParams::lassen();
    let mut w = CsvWriter::create(out_csv, &["bytes", "class", "protocol", "seconds"])?;
    let mut series = Vec::new();
    for class in Locality::ALL {
        let mut pts = Vec::new();
        let mut sz = 1usize;
        while sz <= 1 << 20 {
            let cp = m.class(class);
            let proto = match cp.protocol(sz) {
                crate::model::Protocol::Eager => "eager",
                crate::model::Protocol::Rendezvous => "rendezvous",
            };
            let t = cp.cost(sz);
            w.row(&csv_row![sz, class.label(), proto, format!("{t:.3e}")])?;
            pts.push((sz as f64, t));
            sz *= 4;
        }
        series.push((class.label().to_string(), pts));
    }
    w.flush()?;
    Ok(Figure { title: "Fig 3: ping-pong cost by locality class (Lassen model)".into(), series })
}

/// Figure 7: modeled standard vs locality-aware Bruck vs node count for
/// several PPN values; m/p = one 4-byte integer.
pub fn fig7(out_csv: &str) -> Result<Figure> {
    let cfg = ModelConfig::lassen();
    let n = 4usize; // bytes per process
    let mut w = CsvWriter::create(out_csv, &["nodes", "ppn", "algorithm", "seconds"])?;
    let mut series = Vec::new();
    for ppn in [4usize, 8, 16, 32] {
        let mut std_pts = Vec::new();
        let mut loc_pts = Vec::new();
        let mut nodes = 2usize;
        while nodes <= 1 << 14 {
            let p = nodes * ppn;
            let t_std = cfg.bruck(p, n);
            let t_loc = cfg.loc_bruck(p, ppn, n);
            w.row(&csv_row![nodes, ppn, "bruck", format!("{t_std:.3e}")])?;
            w.row(&csv_row![nodes, ppn, "loc-bruck", format!("{t_loc:.3e}")])?;
            std_pts.push((nodes as f64, t_std));
            loc_pts.push((nodes as f64, t_loc));
            nodes *= 4;
        }
        series.push((format!("bruck ppn={ppn}"), std_pts));
        series.push((format!("loc ppn={ppn}"), loc_pts));
    }
    w.flush()?;
    Ok(Figure { title: "Fig 7: modeled bruck (solid) vs loc-bruck vs node count".into(), series })
}

/// Figure 8: modeled cost vs per-process data size at 1024 regions × 16 ppn.
pub fn fig8(out_csv: &str) -> Result<Figure> {
    let cfg = ModelConfig::lassen();
    let (regions, ppn) = (1024usize, 16usize);
    let p = regions * ppn;
    let mut w = CsvWriter::create(out_csv, &["bytes_per_proc", "algorithm", "seconds"])?;
    let mut std_pts = Vec::new();
    let mut loc_pts = Vec::new();
    let mut n = 4usize;
    while n <= 64 * 1024 {
        let t_std = cfg.bruck(p, n);
        let t_loc = cfg.loc_bruck(p, ppn, n);
        w.row(&csv_row![n, "bruck", format!("{t_std:.3e}")])?;
        w.row(&csv_row![n, "loc-bruck", format!("{t_loc:.3e}")])?;
        std_pts.push((n as f64, t_std));
        loc_pts.push((n as f64, t_loc));
        n *= 4;
    }
    w.flush()?;
    Ok(Figure {
        title: "Fig 8: modeled cost vs data size (1024 regions x 16 ppn)".into(),
        series: vec![("bruck".into(), std_pts), ("loc-bruck".into(), loc_pts)],
    })
}

/// The algorithm set Figures 9/10 compare.
pub const MEASURED_ALGOS: [Algorithm; 5] = [
    Algorithm::SystemDefault,
    Algorithm::Bruck,
    Algorithm::Hierarchical,
    Algorithm::Multilane,
    Algorithm::LocalityBruck,
];

/// Unmeasured executions per figure configuration (plan reused throughout).
pub const WARMUP: usize = 2;
/// Measured executions per figure configuration; the CSV reports the median.
pub const ITERS: usize = 5;
/// Largest world size the proc-backend sweeps spawn (one OS process per
/// rank per data point; sim sweeps continue past this cap).
pub const PROC_MAX_P: usize = 64;

/// Shared engine for Figures 9 and 10: virtual-time execution of every
/// algorithm over real mailbox message schedules.
///
/// Each `(regions, ppn, algorithm)` configuration **plans once** and
/// executes [`WARMUP`]` + `[`ITERS`] times ([`sim::run_allgather_repeated`]),
/// exactly like the paper's timed loops with communicators created outside
/// the timed region; the reported seconds are the median measured
/// iteration and the traffic columns are per-execution.
///
/// `max_p` caps the world size (threads per data point); the paper's node
/// counts extend further, but the shape — who wins and where the gaps
/// open — is established well below the cap.
pub fn measured_figure(
    title: &str,
    machine: &MachineParams,
    ppns: &[usize],
    max_p: usize,
    backend: Backend,
    out_csv: &str,
) -> Result<Figure> {
    let fig = measured_op_figure(OpKind::Allgather, machine, ppns, max_p, backend, out_csv)?;
    Ok(Figure { title: title.into(), series: fig.series })
}

/// Shared sweep engine for every operation: each algorithm of the op
/// (the figure set for allgather, the full registry for allreduce and
/// alltoall), plan-once/execute-`WARMUP + ITERS`, over doubling region
/// counts. Figures 9/10 and the §6 extension sweeps all ride on it.
///
/// With [`Backend::Proc`] each `(regions, ppn)` point up to [`PROC_MAX_P`]
/// also runs on a persistent multi-process pool — one [`ProcPool`] per
/// shape, spawned and handshaken once, serving every algorithm's
/// plan-once/execute-many rows — and the median timed execute lands in a
/// `proc_seconds` CSV column (empty on sim rows) plus a `(proc)` plot
/// series. The regions loop sits outside the algorithm loop for exactly
/// this reason; sim series keep their (measured, model) pair order.
pub fn measured_op_figure(
    op: OpKind,
    machine: &MachineParams,
    ppns: &[usize],
    max_p: usize,
    backend: Backend,
    out_csv: &str,
) -> Result<Figure> {
    let n_vals = 2usize;
    let algos: Vec<&'static str> = match op {
        OpKind::Allgather => MEASURED_ALGOS.iter().map(|a| a.name()).collect(),
        OpKind::Allreduce => crate::collectives::AllreduceRegistry::<u64>::standard().names(),
        OpKind::Alltoall => crate::collectives::AlltoallRegistry::<u64>::standard().names(),
        OpKind::ReduceScatter => {
            crate::collectives::ReduceScatterRegistry::<u64>::standard().names()
        }
    };
    let mut w = CsvWriter::create(
        out_csv,
        &[
            "regions",
            "ppn",
            "algorithm",
            "seconds",
            "predicted_seconds",
            "max_nonlocal_msgs",
            "verified",
            "proc_seconds",
        ],
    )?;
    let mut series = Vec::new();
    for &ppn in ppns {
        let mut pts: Vec<Vec<(f64, f64)>> = vec![Vec::new(); algos.len()];
        let mut pred_pts: Vec<Vec<(f64, f64)>> = vec![Vec::new(); algos.len()];
        let mut proc_pts: Vec<Vec<(f64, f64)>> = vec![Vec::new(); algos.len()];
        let mut regions = 2usize;
        while regions * ppn <= max_p {
            let topo = Topology::regions(regions, ppn);
            let mut pool: Option<ProcPool> = None;
            if backend == Backend::Proc && regions * ppn <= PROC_MAX_P {
                match ProcPool::spawn(regions, ppn, machine.name, &ProcConfig::default()) {
                    Ok(p) => pool = Some(p),
                    Err(e) => eprintln!("warning: proc pool {regions}x{ppn}: {e}"),
                }
            }
            for (ai, algo) in algos.iter().enumerate() {
                let (seconds, predicted, nl, verified) = match op {
                    OpKind::Allgather => {
                        let a = Algorithm::parse(algo).expect("registry name");
                        let rep =
                            sim::run_allgather_repeated(a, &topo, machine, n_vals, WARMUP, ITERS);
                        let nl = rep.trace.max_nonlocal_msgs();
                        (rep.median_vtime, rep.predicted, nl, rep.verified)
                    }
                    OpKind::Allreduce => {
                        let rep = sim::run_allreduce_repeated(
                            algo, &topo, machine, n_vals, WARMUP, ITERS,
                        );
                        let nl = rep.trace.max_nonlocal_msgs();
                        (rep.median_vtime, rep.predicted, nl, rep.verified)
                    }
                    OpKind::Alltoall => {
                        let rep = sim::run_alltoall_repeated(
                            algo, &topo, machine, n_vals, WARMUP, ITERS,
                        );
                        let nl = rep.trace.max_nonlocal_msgs();
                        (rep.median_vtime, rep.predicted, nl, rep.verified)
                    }
                    OpKind::ReduceScatter => {
                        let rep = sim::run_reduce_scatter_repeated(
                            algo, &topo, machine, n_vals, WARMUP, ITERS,
                        );
                        let nl = rep.trace.max_nonlocal_msgs();
                        (rep.median_vtime, rep.predicted, nl, rep.verified)
                    }
                };
                let mut proc_seconds = None;
                let mut drop_pool = false;
                if let Some(pl) = pool.as_mut() {
                    let job =
                        ProcJob::Single { op, algo: (*algo).to_string(), n: n_vals, elem_bytes: 8 };
                    match pool_median_wall(pl, &job, WARMUP, ITERS) {
                        Ok(wsec) => proc_seconds = Some(wsec),
                        Err(e) => {
                            eprintln!(
                                "warning: proc backend skipped {op}/{algo} {regions}x{ppn}: {e}"
                            );
                            // A poisoned pool cannot serve later rows of
                            // this shape; drop it (the next shape spawns
                            // its own anyway).
                            drop_pool = true;
                        }
                    }
                }
                if drop_pool {
                    pool = None;
                }
                w.row(&csv_row![
                    regions,
                    ppn,
                    *algo,
                    format!("{seconds:.3e}"),
                    format!("{predicted:.3e}"),
                    nl,
                    verified,
                    proc_seconds.map(|s| format!("{s:.3e}")).unwrap_or_default()
                ])?;
                pts[ai].push((regions as f64, seconds));
                pred_pts[ai].push((regions as f64, predicted));
                if let Some(s) = proc_seconds {
                    proc_pts[ai].push((regions as f64, s));
                }
            }
            if let Some(mut p) = pool.take() {
                let _ = p.shutdown();
            }
            regions *= 2;
        }
        for (ai, algo) in algos.iter().enumerate() {
            series.push((format!("{algo} ppn={ppn}"), std::mem::take(&mut pts[ai])));
            // The predicted-vs-measured overlay: the IR cost model's curve
            // next to the virtual-time measurement it predicts.
            series.push((format!("{algo} ppn={ppn} (model)"), std::mem::take(&mut pred_pts[ai])));
        }
        // Proc wall-clock series ride after the sim pairs so existing
        // (measured, model) consumers keep their ordering.
        for (ai, algo) in algos.iter().enumerate() {
            if !proc_pts[ai].is_empty() {
                let label = format!("{algo} ppn={ppn} (proc)");
                series.push((label, std::mem::take(&mut proc_pts[ai])));
            }
        }
    }
    w.flush()?;
    Ok(Figure {
        title: format!("{op} cost on the Lassen model (plan-once, median of {ITERS})"),
        series,
    })
}

/// The §6 allreduce sweep: recursive doubling vs locality-aware regional.
pub fn fig_allreduce(out_csv: &str, max_p: usize, backend: Backend) -> Result<Figure> {
    let m = MachineParams::lassen();
    measured_op_figure(OpKind::Allreduce, &m, &[4, 16], max_p, backend, out_csv)
}

/// The §6 alltoall sweep: dispatch, pairwise, Bruck, locality-aware.
pub fn fig_alltoall(out_csv: &str, max_p: usize, backend: Backend) -> Result<Figure> {
    let m = MachineParams::lassen();
    measured_op_figure(OpKind::Alltoall, &m, &[4, 16], max_p, backend, out_csv)
}

/// The reduce-scatter sweep: ring, recursive halving, locality-aware and
/// the model-tuned dispatcher (the allgather's inverse sibling).
pub fn fig_reduce_scatter(out_csv: &str, max_p: usize, backend: Backend) -> Result<Figure> {
    let m = MachineParams::lassen();
    measured_op_figure(OpKind::ReduceScatter, &m, &[4, 16], max_p, backend, out_csv)
}

/// Figure 9: Quartz (node regions).
pub fn fig9(out_csv: &str, max_p: usize, backend: Backend) -> Result<Figure> {
    measured_figure(
        "Fig 9: measured (virtual-time) allgather cost on Quartz model",
        &MachineParams::quartz(),
        &[4, 16],
        max_p,
        backend,
        out_csv,
    )
}

/// Figure 10: Lassen (socket regions; single socket per node used, so
/// non-local = inter-node exactly as in the paper's setup).
pub fn fig10(out_csv: &str, max_p: usize, backend: Backend) -> Result<Figure> {
    measured_figure(
        "Fig 10: measured (virtual-time) allgather cost on Lassen model",
        &MachineParams::lassen(),
        &[4, 16],
        max_p,
        backend,
        out_csv,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> String {
        std::env::temp_dir()
            .join(format!("locag_fig_{name}_{}.csv", std::process::id()))
            .to_string_lossy()
            .into_owned()
    }

    #[test]
    fn fig3_has_three_ordered_series() {
        let f = fig3(&tmp("f3")).unwrap();
        assert_eq!(f.series.len(), 3);
        // at every x, intra-socket < inter-node
        let intra = &f.series[0].1;
        let internode = &f.series[2].1;
        for (a, b) in intra.iter().zip(internode) {
            assert!(a.1 < b.1);
        }
        assert!(f.plot().contains("Fig 3"));
    }

    #[test]
    fn fig7_loc_wins_at_scale() {
        let f = fig7(&tmp("f7")).unwrap();
        // last ppn=32 pair: loc-bruck below bruck at the largest node count
        let bruck32 = &f.series[6].1;
        let loc32 = &f.series[7].1;
        assert!(loc32.last().unwrap().1 < bruck32.last().unwrap().1);
    }

    #[test]
    fn fig8_improvement_insensitive_to_size() {
        let f = fig8(&tmp("f8")).unwrap();
        let std_s = &f.series[0].1;
        let loc_s = &f.series[1].1;
        // ratio roughly stable across sizes (paper: "no notable effect")
        let r_first = std_s[0].1 / loc_s[0].1;
        let r_last = std_s.last().unwrap().1 / loc_s.last().unwrap().1;
        assert!(r_first > 1.0 && r_last > 1.0);
    }

    #[test]
    fn op_figures_small_sweeps_produce_series() {
        for op in [OpKind::Allreduce, OpKind::Alltoall, OpKind::ReduceScatter] {
            let f = measured_op_figure(
                op,
                &MachineParams::lassen(),
                &[4],
                32,
                Backend::Sim,
                &tmp(op.name()),
            )
            .unwrap();
            assert!(!f.series.is_empty(), "{op}");
            for (label, pts) in &f.series {
                assert!(!pts.is_empty(), "{op} {label}");
            }
        }
    }

    #[test]
    fn measured_figure_small_sweep_verifies() {
        let f = measured_figure("t", &MachineParams::quartz(), &[4], 64, Backend::Sim, &tmp("f9s"))
            .unwrap();
        // one measured + one predicted-overlay series per algorithm; sim
        // sweeps never grow a `(proc)` series
        assert_eq!(f.series.len(), 2 * MEASURED_ALGOS.len());
        for (label, pts) in &f.series {
            assert!(!pts.is_empty());
            assert!(!label.contains("(proc)"), "{label}");
        }
    }

    #[test]
    fn predicted_overlay_matches_measured_exactly() {
        // The overlay is the IR cost model's prediction; on the virtual
        // transport it equals the measurement.
        let f = measured_figure("t", &MachineParams::lassen(), &[4], 32, Backend::Sim, &tmp("ovl"))
            .unwrap();
        for pair in f.series.chunks(2) {
            let (measured, predicted) = (&pair[0], &pair[1]);
            assert!(predicted.0.ends_with("(model)"), "{}", predicted.0);
            for (m, p) in measured.1.iter().zip(&predicted.1) {
                assert!(
                    (m.1 - p.1).abs() < 1e-12,
                    "{}: measured {:.3e} vs predicted {:.3e}",
                    measured.0,
                    m.1,
                    p.1
                );
            }
        }
    }
}
