//! Benchmark support: a small wall-clock measurement kit (offline stand-in
//! for criterion) and the figure generators that regenerate every plot of
//! the paper's evaluation ([`figures`]).

pub mod figures;
pub mod perf_gate;

use std::time::Instant;

use crate::util::stats::Summary;

/// One benchmark measurement series.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    /// Per-iteration seconds.
    pub samples: Vec<f64>,
}

impl Measurement {
    /// Summary statistics of the samples.
    pub fn summary(&self) -> Summary {
        Summary::of(&self.samples).expect("measurement has samples")
    }

    /// Render one line like criterion's output.
    pub fn report_line(&self) -> String {
        use crate::util::fmt::seconds;
        let s = self.summary();
        format!(
            "{:<44} median {:>10}  p10 {:>10}  p90 {:>10}  (n={})",
            self.name,
            seconds(s.p50),
            seconds(s.p10),
            seconds(s.p90),
            s.n
        )
    }
}

/// Measure `f` after `warmup` unmeasured runs; `iters` measured runs.
pub fn measure<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    Measurement { name: name.to_string(), samples }
}

/// Measure with a time budget: run until `budget_secs` elapses (at least
/// `min_iters`), so fast and slow cases both get stable medians.
pub fn measure_budget<F: FnMut()>(
    name: &str,
    warmup: usize,
    budget_secs: f64,
    min_iters: usize,
    mut f: F,
) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters || start.elapsed().as_secs_f64() < budget_secs {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
        if samples.len() > 100_000 {
            break;
        }
    }
    Measurement { name: name.to_string(), samples }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_collects_samples() {
        let m = measure("noop", 2, 10, || {});
        assert_eq!(m.samples.len(), 10);
        assert!(m.report_line().contains("noop"));
        assert!(m.summary().p50 >= 0.0);
    }

    #[test]
    fn measure_budget_hits_min_iters() {
        let m = measure_budget("spin", 0, 0.0, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert!(m.samples.len() >= 5);
    }
}
